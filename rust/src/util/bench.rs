//! Micro-benchmark harness (criterion is not available in this image).
//!
//! Warmup + timed iterations with median/mean/p95 reporting and a simple
//! throughput annotation. `cargo bench` runs `rust/benches/bench_main.rs`
//! (`harness = false`) which drives this.

use std::time::{Duration, Instant};

use super::stats;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
    /// Optional elements-per-iteration for throughput reporting.
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.elements
            .map(|e| e as f64 / (self.median_ns / 1e9))
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:.2} Gelem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:.2} Melem/s", t / 1e6),
            Some(t) => format!("  {:.0} elem/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} median {:>12} mean {:>12} p95  ({} iters){}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            self.iters,
            tp
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: Duration,
    pub target_time: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(300),
            target_time: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 100_000,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            target_time: Duration::from_millis(300),
            min_iters: 3,
            max_iters: 10_000,
        }
    }

    /// Run `f` repeatedly; `f` should perform one unit of work and return a
    /// value that is black-boxed to prevent dead-code elimination.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup + calibrate a single-iteration estimate.
        let wstart = Instant::now();
        let mut calib = Vec::new();
        while wstart.elapsed() < self.warmup || calib.len() < 2 {
            let t = Instant::now();
            black_box(f());
            calib.push(t.elapsed().as_nanos() as f64);
            if calib.len() > 1000 {
                break;
            }
        }
        let est = stats::median(&calib).max(1.0);
        let iters = ((self.target_time.as_nanos() as f64 / est) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: stats::mean(&samples),
            median_ns: stats::median(&samples),
            p95_ns: stats::percentile(&samples, 95.0),
            stddev_ns: stats::stddev(&samples),
            elements: None,
        }
    }

    pub fn run_with_elements<T, F: FnMut() -> T>(
        &self,
        name: &str,
        elements: u64,
        f: F,
    ) -> BenchResult {
        let mut r = self.run(name, f);
        r.elements = Some(elements);
        r
    }
}

/// Optimization barrier (std::hint::black_box is stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// ---------------------------------------------------------------------------
// Machine-readable output (BENCH_step.json)
// ---------------------------------------------------------------------------

use super::json::Json;

impl BenchResult {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("median_ns", Json::num(self.median_ns)),
            ("mean_ns", Json::num(self.mean_ns)),
            ("p95_ns", Json::num(self.p95_ns)),
            ("stddev_ns", Json::num(self.stddev_ns)),
            ("iters", Json::num(self.iters as f64)),
        ];
        if let Some(e) = self.elements {
            pairs.push(("elements", Json::num(e as f64)));
            if let Some(tp) = self.throughput() {
                pairs.push(("elements_per_sec", Json::num(tp)));
            }
        }
        Json::obj(pairs)
    }
}

/// Serialize a bench run to the `lisa-bench-v1` JSON schema (written as
/// `BENCH_step.json` at the repo root by `cargo bench`, consumed by the
/// perf-trajectory tooling and CI's bench smoke job).
pub fn results_to_json(results: &[BenchResult], quick: bool, note: &str) -> Json {
    let groups = Json::Obj(
        results
            .iter()
            .map(|r| (r.name.clone(), r.to_json()))
            .collect(),
    );
    Json::obj(vec![
        ("schema", Json::str("lisa-bench-v1")),
        ("quick", Json::Bool(quick)),
        ("note", Json::str(note)),
        ("groups", groups),
    ])
}

/// Write the bench JSON to `path` (best-effort caller decides the path).
pub fn write_json(
    path: &std::path::Path,
    results: &[BenchResult],
    quick: bool,
    note: &str,
) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", results_to_json(results, quick, note)))
}

/// Append one run to the bench trajectory (`BENCH_trajectory.jsonl` at
/// the repo root): a single JSON line per `cargo bench` invocation, so
/// perf history accumulates across commits instead of being overwritten
/// the way the `BENCH_step.json` snapshot is. Schema
/// `lisa-bench-trajectory-v1`: the snapshot object plus a Unix
/// timestamp.
pub fn append_trajectory(
    path: &std::path::Path,
    results: &[BenchResult],
    quick: bool,
    note: &str,
) -> std::io::Result<()> {
    use std::io::Write;
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let line = Json::obj(vec![
        ("schema", Json::str("lisa-bench-trajectory-v1")),
        ("unix_s", Json::num(unix_s as f64)),
        ("quick", Json::Bool(quick)),
        ("note", Json::str(note)),
        (
            "groups",
            Json::Obj(results.iter().map(|r| (r.name.clone(), r.to_json())).collect()),
        ),
    ]);
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "{line}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let r = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.median_ns > 0.0);
        assert!(r.iters >= 3);
    }

    #[test]
    fn throughput_annotation() {
        let b = Bench::quick();
        let r = b.run_with_elements("tp", 1_000, || 0u8);
        assert!(r.throughput().unwrap() > 0.0);
        assert!(r.report().contains("elem/s"));
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(1500.0).contains("µs"));
        assert!(fmt_ns(2.5e6).contains("ms"));
        assert!(fmt_ns(3.2e9).contains(" s"));
    }

    #[test]
    fn trajectory_appends_one_parseable_line_per_run() {
        let b = Bench::quick();
        let r = b.run_with_elements("serve/quant-tiny", 64, || 1u8);
        let dir = std::env::temp_dir().join(format!("lisa-bench-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_trajectory.jsonl");
        let _ = std::fs::remove_file(&path);
        append_trajectory(&path, std::slice::from_ref(&r), true, "run one").unwrap();
        append_trajectory(&path, std::slice::from_ref(&r), false, "run two").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "append-per-run, one line each: {text}");
        for (i, line) in lines.iter().enumerate() {
            let j = crate::util::json::Json::parse(line).unwrap();
            assert_eq!(j.path("schema").unwrap().as_str(), Some("lisa-bench-trajectory-v1"));
            assert_eq!(j.path("quick").unwrap().as_bool(), Some(i == 0));
            assert!(j.path("groups").unwrap().get("serve/quant-tiny").is_some());
        }
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn json_schema_roundtrips() {
        let b = Bench::quick();
        let r1 = b.run_with_elements("step/x", 100, || 1u8);
        let r2 = b.run("host/y", || 2u8);
        let j = results_to_json(&[r1, r2], true, "unit test");
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.path("schema").unwrap().as_str(), Some("lisa-bench-v1"));
        assert_eq!(parsed.path("quick").unwrap().as_bool(), Some(true));
        let g = parsed.path("groups").unwrap();
        let step = g.get("step/x").unwrap();
        assert!(step.get("median_ns").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(step.get("elements").unwrap().as_usize(), Some(100));
        assert!(g.get("host/y").unwrap().get("elements").is_none());
    }
}
