//! Deterministic RNG for the whole coordinator.
//!
//! LISA's layer-selection reproducibility (Table 7 / Fig 10 seed studies)
//! requires a seedable, splittable generator under our control — crates.io
//! `rand` is unavailable in this image, so this is xoshiro256** seeded via
//! SplitMix64 (Blackman & Vigna), the same construction `rand_xoshiro` uses.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (used to give each experiment arm /
    /// layer-sampler / data shard its own generator from one master seed).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    /// Raw generator state, for checkpointing: a generator rebuilt via
    /// [`Rng::from_state`] continues the exact same stream.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a checkpointed [`Rng::state`]. The all-zero
    /// state is xoshiro's single invalid fixed point (the stream would be
    /// all zeros forever), so it is rejected as corruption.
    pub fn from_state(s: [u64; 4]) -> anyhow::Result<Rng> {
        anyhow::ensure!(
            s.iter().any(|&w| w != 0),
            "invalid RNG state: all-zero (corrupt checkpoint?)"
        );
        Ok(Rng { s })
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free is overkill here; modulo
        // bias at n << 2^64 is negligible for layer sampling.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller (cached spare omitted for simplicity).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill with N(0, std^2) float32s.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = self.normal_f32() * std;
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm), sorted.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }

    /// Weighted sample of one index proportional to `w`. Every weight must
    /// be finite and non-negative with positive total mass — NaN/∞/negative
    /// entries would silently skew the cumulative walk, so they are
    /// rejected loudly.
    pub fn sample_weighted(&mut self, w: &[f64]) -> usize {
        let mut total = 0.0f64;
        for (i, &wi) in w.iter().enumerate() {
            assert!(
                wi.is_finite() && wi >= 0.0,
                "sample_weighted: weight[{i}] = {wi} (must be finite and >= 0)"
            );
            total += wi;
        }
        assert!(total > 0.0, "sample_weighted: all-zero weights");
        let mut r = self.f64() * total;
        for (i, &wi) in w.iter().enumerate() {
            r -= wi;
            if r <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(11);
        for _ in 0..100 {
            let n = 2 + r.below(30);
            let k = r.below(n + 1);
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted distinct");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_sampling_bias() {
        let mut r = Rng::new(9);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.sample_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.0..4.5).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    #[should_panic(expected = "must be finite and >= 0")]
    fn weighted_sampling_rejects_negative() {
        Rng::new(1).sample_weighted(&[1.0, -0.5, 2.0]);
    }

    #[test]
    #[should_panic(expected = "must be finite and >= 0")]
    fn weighted_sampling_rejects_non_finite() {
        Rng::new(1).sample_weighted(&[1.0, f64::NAN, 2.0]);
    }

    #[test]
    #[should_panic(expected = "all-zero weights")]
    fn weighted_sampling_rejects_zero_mass() {
        Rng::new(1).sample_weighted(&[0.0, 0.0]);
    }

    #[test]
    fn state_roundtrip_continues_stream_identically() {
        let mut a = Rng::new(42);
        for _ in 0..57 {
            a.next_u64(); // advance to an arbitrary mid-stream point
        }
        let saved = a.state();
        let mut b = Rng::from_state(saved).unwrap();
        for i in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64(), "diverged at draw {i}");
        }
        // the restored generator exercises every sampling surface the same
        let mut c = Rng::from_state(saved).unwrap();
        let mut d = Rng::from_state(saved).unwrap();
        for _ in 0..100 {
            assert_eq!(c.f64().to_bits(), d.f64().to_bits());
            assert_eq!(c.sample_distinct(16, 4), d.sample_distinct(16, 4));
            assert_eq!(c.normal().to_bits(), d.normal().to_bits());
        }
    }

    #[test]
    fn state_save_does_not_perturb_stream() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let _ = a.state(); // observing state must not advance it
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn all_zero_state_rejected() {
        assert!(Rng::from_state([0; 4]).is_err());
        assert!(Rng::from_state([0, 0, 1, 0]).is_ok());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
