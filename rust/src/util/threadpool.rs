//! Scoped parallel-map over std threads (no rayon/tokio in this image).
//!
//! Used for data-parallel host work: optimizer updates across parameter
//! tensors, corpus generation shards, and running independent experiment
//! arms concurrently. PJRT executions stay on the calling thread — the CPU
//! client is already internally multi-threaded.

/// Run `f(i, &items[i])` for every item on up to `workers` threads and
/// collect results in input order.
pub fn parallel_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let out_ptr = SendPtr(out.as_mut_ptr());

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            let items = &items;
            let out_ptr = &out_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                // SAFETY: each index is claimed by exactly one worker via the
                // atomic counter, so writes to out[i] never alias.
                unsafe {
                    *out_ptr.0.add(i) = Some(r);
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker wrote every slot")).collect()
}

struct SendPtr<T>(*mut T);
// SAFETY: SendPtr is only used inside `scope` above, where the atomic
// index counter hands each slot to exactly one worker — no two threads
// ever dereference the same offset, and the pointee outlives the scope.
unsafe impl<T> Sync for SendPtr<T> {}
// SAFETY: as above — exclusive slot ownership per worker within the
// scope makes moving the pointer across threads sound.
unsafe impl<T> Send for SendPtr<T> {}

/// Default worker count: physical parallelism minus one (leave a core for
/// the PJRT client's own pool), at least 1.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

/// Split `n` items into per-worker contiguous (start, len) chunks.
pub fn chunks(n: usize, workers: usize) -> Vec<(usize, usize)> {
    let workers = workers.clamp(1, n.max(1));
    let base = n / workers;
    let rem = n % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let len = base + usize::from(w < rem);
        if len > 0 {
            out.push((start, len));
        }
        start += len;
    }
    out
}

/// Parallel for over mutable chunks of a slice (optimizer hot path: each
/// worker owns a disjoint subrange of the flat parameter buffer).
pub fn parallel_chunks_mut<T, F>(data: &mut [T], workers: usize, chunk_of: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let parts = chunks(n, workers);
    if parts.len() == 1 {
        chunk_of(0, data);
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut offset = 0;
        for (_, len) in parts {
            let (head, tail) = rest.split_at_mut(len);
            let chunk_of = &chunk_of;
            let start = offset;
            scope.spawn(move || chunk_of(start, head));
            rest = tail;
            offset += len;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_single_worker_and_empty() {
        let out = parallel_map(&[1, 2, 3], 1, |i, &x| i + x);
        assert_eq!(out, vec![1, 3, 5]);
        let empty: Vec<i32> = parallel_map(&[] as &[i32], 4, |_, &x| x);
        assert!(empty.is_empty());
    }

    #[test]
    fn chunk_partition_covers_everything() {
        for n in [0usize, 1, 7, 64, 101] {
            for w in [1usize, 2, 3, 8] {
                let parts = chunks(n, w);
                let total: usize = parts.iter().map(|(_, l)| l).sum();
                assert_eq!(total, n);
                let mut pos = 0;
                for (s, l) in parts {
                    assert_eq!(s, pos);
                    pos += l;
                }
            }
        }
    }

    #[test]
    fn chunks_mut_touches_all() {
        let mut v = vec![0u32; 1000];
        parallel_chunks_mut(&mut v, 7, |start, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = (start + i) as u32;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i as u32);
        }
    }
}
