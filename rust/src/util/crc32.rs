//! CRC-32 (IEEE 802.3, reflected, polynomial 0xEDB88320) — the checksum
//! guarding checkpoint integrity (`model::checkpoint` v2 writes one per
//! serialized record). Hand-rolled because no checksum crate ships in this
//! image; the table is built at compile time.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Incremental CRC-32 accumulator (feed bytes with `update`, read the
/// digest with `finish`; the accumulator stays usable afterwards).
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

/// One-shot convenience.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Reference values from the standard CRC-32 ("check" = 0xCBF43926).
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn incremental_matches_oneshot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let mut c = Crc32::new();
        c.update(&data[..10]);
        c.update(&data[10..]);
        assert_eq!(c.finish(), crc32(data));
    }

    #[test]
    fn detects_single_bit_flips() {
        let mut data = vec![0u8; 64];
        for (i, b) in data.iter_mut().enumerate() {
            *b = (i * 7) as u8;
        }
        let clean = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                data[byte] ^= 1 << bit;
                assert_ne!(crc32(&data), clean, "flip at {byte}:{bit} undetected");
                data[byte] ^= 1 << bit;
            }
        }
    }
}
