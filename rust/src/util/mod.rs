//! Infrastructure substrates built from scratch for this image (no
//! crates.io beyond the `xla` closure — see DESIGN.md §2).

pub mod bench;
pub mod cast;
pub mod cli;
pub mod crc32;
pub mod csv;
pub mod hist;
pub mod json;
pub mod logger;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
