//! Small statistics helpers shared by the bench harness, eval and reports.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Percentile with linear interpolation, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// L2 norm of a float32 tensor buffer.
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Max |a - b| over two equal-length buffers.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Relative-tolerance allclose (numpy semantics: |a-b| <= atol + rtol*|b|).
pub fn allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| (x - y).abs() <= atol + rtol * y.abs())
}

/// Exponential moving average accumulator (loss smoothing in reports).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v * (1.0 - self.alpha) + x * self.alpha,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - 1.2909944487358056).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn allclose_tolerances() {
        assert!(allclose(&[1.0, 2.0], &[1.0 + 1e-6, 2.0], 1e-5, 1e-7));
        assert!(!allclose(&[1.0], &[1.1], 1e-5, 1e-7));
        assert!(!allclose(&[1.0], &[1.0, 2.0], 1e-5, 1e-7));
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..50 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn norms() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 5.0]), 0.5);
    }
}
