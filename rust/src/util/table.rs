//! Aligned-text and markdown table printer for the experiment harness.
//!
//! Every `exp <id>` driver emits its paper-table reproduction through this,
//! so EXPERIMENTS.md rows are copy-pasteable from stdout.

#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// GitHub-flavored markdown rendering.
    pub fn markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, width) in cells.iter().zip(w) {
                s.push_str(&format!(" {:<width$} |", c, width = width));
            }
            s
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('\n');
        out.push('|');
        for width in &w {
            out.push_str(&format!("{:-<w$}|", "", w = width + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (for results/*.csv dumps).
    pub fn csv(&self) -> String {
        let esc = |c: &str| -> String {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.markdown());
    }
}

/// Format a float with `p` significant-looking decimals, trimming noise.
pub fn fnum(x: f64, p: usize) -> String {
    format!("{:.p$}", x, p = p)
}

/// Human bytes: 1536 -> "1.5K", 2147483648 -> "2.0G".
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "K", "M", "G", "T"];
    let mut x = b as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{x:.1}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(vec!["method", "score"]);
        t.row(vec!["LISA", "4.94"]);
        t.row(vec!["LoRA", "4.45"]);
        let md = t.markdown();
        assert!(md.starts_with("| method | score |"));
        assert_eq!(md.lines().count(), 4);
        assert!(md.contains("| LISA   | 4.94  |"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x,y", "q\"z"]);
        assert_eq!(t.csv(), "a,b\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        Table::new(vec!["a", "b"]).row(vec!["only-one"]);
    }

    #[test]
    fn bytes() {
        assert_eq!(human_bytes(512), "512B");
        assert_eq!(human_bytes(1536), "1.5K");
        assert_eq!(human_bytes(2 * 1024 * 1024 * 1024), "2.0G");
    }
}
