//! Fixed-bucket latency histograms for the serving metrics endpoint
//! (DESIGN.md §11).
//!
//! Thread-safe by construction: counts are relaxed atomics and the sum is
//! a bit-CAS'd f64, so HTTP workers observe while the `/metrics` handler
//! renders without a lock.
//!
//! **Ordering audit.** `Relaxed` is deliberate and sufficient here: each
//! counter is an independent monotone tally, the CAS loop on `sum_bits`
//! is made atomic by the compare-exchange itself (no other memory is
//! published under it), and readers only ever see a *slightly stale*
//! snapshot — never a torn or decreasing one. Nothing synchronizes
//! *through* a histogram; cross-field consistency (e.g. a rendered
//! `_count` lagging `_sum` by an in-flight observation) is explicitly
//! tolerated by the Prometheus scrape model. The one place the metrics
//! layer does need ordering — the dirty-flag handoff in
//! `serve_http/metrics.rs` — uses a Release store paired with an
//! Acquire swap. `lisa_hist_hammer` in the tests pins the
//! lose-nothing guarantee under contention.
//!
//! Buckets are cumulative in the rendered output
//! (Prometheus `histogram` exposition: `_bucket{le="..."}`, `_sum`,
//! `_count`) and quantiles are estimated by linear interpolation inside
//! the owning bucket — good enough for p50/p99 gauges on serving
//! latencies, where bucket bounds grow exponentially.

use std::sync::atomic::{AtomicU64, Ordering};

/// A histogram with fixed upper bounds plus an implicit `+Inf` bucket.
#[derive(Debug)]
pub struct Histogram {
    /// Ascending finite upper bounds; `counts` has one extra `+Inf` slot.
    bounds: Vec<f64>,
    counts: Vec<AtomicU64>,
    /// f64 bits, updated by compare-exchange (no atomic f64 in std).
    sum_bits: AtomicU64,
}

impl Histogram {
    /// `bounds` must be ascending and finite; an `+Inf` overflow bucket
    /// is appended implicitly.
    pub fn new(bounds: Vec<f64>) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]) && bounds.iter().all(|b| b.is_finite()),
            "histogram bounds must be ascending and finite"
        );
        let counts = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram { bounds, counts, sum_bits: AtomicU64::new(0f64.to_bits()) }
    }

    /// `n` exponentially growing bounds starting at `start` with the
    /// given `factor` (the Prometheus `exponential_buckets` shape).
    pub fn exponential(start: f64, factor: f64, n: usize) -> Histogram {
        assert!(start > 0.0 && factor > 1.0 && n >= 1);
        let mut bounds = Vec::with_capacity(n);
        let mut b = start;
        for _ in 0..n {
            bounds.push(b);
            b *= factor;
        }
        Histogram::new(bounds)
    }

    /// Record one value (non-finite values count into `+Inf` and are
    /// excluded from the sum, so a stray NaN can't poison the export).
    pub fn observe(&self, v: f64) {
        let i = if v.is_finite() {
            self.bounds.partition_point(|b| *b < v)
        } else {
            self.bounds.len()
        };
        self.counts[i].fetch_add(1, Ordering::Relaxed);
        if v.is_finite() {
            let mut cur = self.sum_bits.load(Ordering::Relaxed);
            loop {
                let next = (f64::from_bits(cur) + v).to_bits();
                match self.sum_bits.compare_exchange_weak(
                    cur,
                    next,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(seen) => cur = seen,
                }
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Estimated quantile (`q` in [0, 1]): linear interpolation inside
    /// the bucket holding the target rank; the overflow bucket reports
    /// its lower bound. 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * total as f64).max(1.0);
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if (cum as f64) >= rank {
                if i == self.bounds.len() {
                    return self.bounds[self.bounds.len() - 1]; // +Inf bucket
                }
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = self.bounds[i];
                let into = rank - (cum - c) as f64;
                return lo + (hi - lo) * (into / c as f64);
            }
        }
        self.bounds[self.bounds.len() - 1]
    }

    /// Prometheus exposition lines for a histogram named `name` (caller
    /// provides the `# TYPE` header): cumulative `_bucket` rows, `_sum`,
    /// `_count`.
    pub fn render_prometheus(&self, name: &str, out: &mut String) {
        use std::fmt::Write;
        let mut cum = 0u64;
        for (i, b) in self.bounds.iter().enumerate() {
            cum += self.counts[i].load(Ordering::Relaxed);
            let _ = writeln!(out, "{name}_bucket{{le=\"{b}\"}} {cum}");
        }
        cum += self.counts[self.bounds.len()].load(Ordering::Relaxed);
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        let _ = writeln!(out, "{name}_sum {}", self.sum());
        let _ = writeln!(out, "{name}_count {cum}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observations_land_in_the_right_buckets() {
        let h = Histogram::new(vec![1.0, 2.0, 4.0]);
        for v in [0.5, 1.0, 1.5, 3.0, 100.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 106.0).abs() < 1e-9);
        // le-counts: <=1: 2 (0.5, 1.0 — bounds are inclusive), <=2: +1, <=4: +1, +Inf: +1
        let mut s = String::new();
        h.render_prometheus("x", &mut s);
        assert!(s.contains("x_bucket{le=\"1\"} 2"), "{s}");
        assert!(s.contains("x_bucket{le=\"2\"} 3"), "{s}");
        assert!(s.contains("x_bucket{le=\"4\"} 4"), "{s}");
        assert!(s.contains("x_bucket{le=\"+Inf\"} 5"), "{s}");
        assert!(s.contains("x_sum 106"), "{s}");
        assert!(s.contains("x_count 5"), "{s}");
    }

    #[test]
    fn quantiles_interpolate_and_empty_is_zero() {
        let h = Histogram::new(vec![10.0, 20.0, 40.0]);
        assert_eq!(h.quantile(0.5), 0.0);
        for _ in 0..100 {
            h.observe(15.0); // all in (10, 20]
        }
        let p50 = h.quantile(0.5);
        assert!((10.0..=20.0).contains(&p50), "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((10.0..=20.0).contains(&p99), "p99={p99}");
        h.observe(1000.0); // overflow bucket reports the top bound
        assert_eq!(h.quantile(1.0), 40.0);
    }

    #[test]
    fn exponential_bounds_grow_geometrically() {
        let h = Histogram::exponential(0.001, 2.0, 4);
        assert_eq!(h.bounds, vec![0.001, 0.002, 0.004, 0.008]);
    }

    #[test]
    fn non_finite_observations_cannot_poison_the_sum() {
        let h = Histogram::new(vec![1.0]);
        h.observe(f64::NAN);
        h.observe(f64::INFINITY);
        h.observe(0.5);
        assert_eq!(h.count(), 3);
        assert!((h.sum() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn concurrent_observers_lose_nothing() {
        let h = std::sync::Arc::new(Histogram::exponential(1.0, 2.0, 10));
        let mut joins = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    h.observe((t * 1000 + i) as f64 % 700.0 + 1.0);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
        assert!(h.sum() > 0.0);
    }

    /// The TSan-shaped hammer: writers race readers (`render_prometheus`
    /// and `quantile` run mid-stream) and the final tallies must be
    /// exact. Observing `1.0` keeps every partial sum representable, so
    /// any lost CAS update or torn read shows up as a hard inequality,
    /// not float noise. This is also the test the CI ThreadSanitizer job
    /// runs over `--lib` (`.github/workflows/ci.yml`).
    #[test]
    fn lisa_hist_hammer_exact_under_reader_writer_races() {
        const WRITERS: usize = 8;
        const PER: usize = 5_000;
        let h = std::sync::Arc::new(Histogram::exponential(0.5, 2.0, 6));
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..2 {
            let h = h.clone();
            let stop = stop.clone();
            readers.push(std::thread::spawn(move || {
                let mut renders = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let mut s = String::new();
                    h.render_prometheus("hammer", &mut s);
                    // monotone sanity on the racing snapshot
                    assert!(h.quantile(0.5) >= 0.0);
                    assert!(h.sum() >= 0.0);
                    renders += 1;
                }
                renders
            }));
        }
        let writers: Vec<_> = (0..WRITERS)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for _ in 0..PER {
                        h.observe(1.0);
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            assert!(r.join().unwrap() > 0, "reader never ran");
        }
        let n = (WRITERS * PER) as u64;
        assert_eq!(h.count(), n, "lost bucket increments under contention");
        assert_eq!(h.sum(), n as f64, "lost CAS sum updates under contention");
    }
}
