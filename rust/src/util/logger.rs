//! Tiny `log`-facade backend writing leveled, timestamped lines to stderr.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::LazyLock;
use std::time::Instant;

static START: LazyLock<Instant> = LazyLock::new(Instant::now);
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed();
        eprintln!(
            "[{:>8.3}s {} {}] {}",
            t.as_secs_f64(),
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger once; level from `LISA_LOG` (error..trace, default
/// info). Safe to call repeatedly.
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    LazyLock::force(&START);
    let level = match std::env::var("LISA_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
