//! Minimal JSON parser + writer.
//!
//! The image has no crates.io access beyond the `xla` closure (no `serde`),
//! so configuration files, artifact manifests and metric dumps go through
//! this hand-rolled implementation. It supports the full JSON grammar minus
//! exotic number formats; numbers are stored as `f64` (adequate: manifests
//! carry shapes and hyperparameters only).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")`
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.b.len()
                        && (self.b[self.pos] & 0xc0) == 0x80
                    {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.path("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
                   Some("x"));
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",true,null],"n":{"x":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
