//! Property-testing mini-framework (proptest is not available offline).
//!
//! A property is a closure over a seeded [`crate::util::rng::Rng`]; the
//! runner executes it across many random cases and, on failure, reports the
//! failing case seed so the exact input regenerates deterministically:
//!
//! ```ignore
//! prop_check("adamw matches ref", 256, |rng| {
//!     let n = 1 + rng.below(512);
//!     ...
//!     prop_assert!(close, "diff={diff}");
//!     Ok(())
//! });
//! ```
//!
//! Used by the coordinator invariants (LISA sampler distribution, engine
//! freeze-mask routing, optimizer state management) — see rust/tests/.

use super::rng::Rng;

pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `prop`, each with a deterministic per-case
/// RNG derived from `base_seed`. Panics with the failing seed on error.
pub fn prop_check_seeded<F>(name: &str, base_seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (reproduce with seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Default-seed variant; override the seed with env `LISA_PROP_SEED` to
/// replay a failure.
pub fn prop_check<F>(name: &str, cases: usize, prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let base = std::env::var("LISA_PROP_SEED")
        .ok()
        .and_then(|s| {
            let s = s.trim_start_matches("0x");
            u64::from_str_radix(s, 16).ok()
        })
        .unwrap_or(0xC0FFEE);
    prop_check_seeded(name, base, cases, prop)
}

/// Assert inside a property, returning Err instead of panicking so the
/// runner can attach the case seed.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Assert approximate equality of two f32 slices inside a property.
#[macro_export]
macro_rules! prop_assert_allclose {
    ($a:expr, $b:expr, $rtol:expr, $atol:expr) => {{
        let (a, b) = (&$a, &$b);
        if a.len() != b.len() {
            return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
        }
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            let tol = $atol + $rtol * y.abs();
            if (x - y).abs() > tol {
                return Err(format!(
                    "allclose failed at [{i}]: {x} vs {y} (tol {tol})"
                ));
            }
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check("trivial", 50, |rng| {
            count += 1;
            let x = rng.f64();
            prop_assert!((0.0..1.0).contains(&x));
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        prop_check("fails", 10, |rng| {
            let x = rng.below(4);
            prop_assert!(x < 3, "x was {x}");
            Ok(())
        });
    }

    #[test]
    fn deterministic_cases() {
        let mut first = Vec::new();
        prop_check_seeded("det", 1234, 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        prop_check_seeded("det", 1234, 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn allclose_macro() {
        fn go() -> super::PropResult {
            prop_assert_allclose!([1.0f32, 2.0], [1.0f32, 2.0 + 1e-7], 1e-5, 1e-6);
            Ok(())
        }
        assert!(go().is_ok());
        fn bad() -> super::PropResult {
            prop_assert_allclose!([1.0f32], [2.0f32], 1e-5, 1e-6);
            Ok(())
        }
        assert!(bad().is_err());
    }
}
