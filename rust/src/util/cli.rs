//! Hand-rolled CLI argument parser (no `clap` in this image).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, repeated
//! positionals, and typed getters with defaults. Each binary/subcommand
//! declares its options for `--help` rendering.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
    spec: Vec<(String, String, String)>, // (name, default, help)
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown option --{0} (try --help)")]
    Unknown(String),
    #[error("option --{0} expects a value")]
    MissingValue(String),
    #[error("option --{0}: cannot parse '{1}' as {2}")]
    BadValue(String, String, &'static str),
}

impl Args {
    /// Parse raw args against a declared option spec.
    /// `spec`: (name, default ("" = no default, "false" for flags), help).
    pub fn parse(
        raw: &[String],
        spec: &[(&str, &str, &str)],
    ) -> Result<Args, CliError> {
        let known: BTreeMap<&str, &str> =
            spec.iter().map(|(n, d, _)| (*n, *d)).collect();
        let mut out = Args {
            spec: spec
                .iter()
                .map(|(n, d, h)| (n.to_string(), d.to_string(), h.to_string()))
                .collect(),
            ..Default::default()
        };
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if key == "help" {
                    out.flags.entry("help".into()).or_default().push("true".into());
                    i += 1;
                    continue;
                }
                let default = known
                    .get(key.as_str())
                    .ok_or_else(|| CliError::Unknown(key.clone()))?;
                let is_bool_flag = *default == "false" || *default == "true";
                let val = match inline_val {
                    Some(v) => v,
                    None if is_bool_flag => "true".to_string(),
                    None => {
                        i += 1;
                        raw.get(i)
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(key.clone()))?
                    }
                };
                out.flags.entry(key).or_default().push(val);
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn wants_help(&self) -> bool {
        self.flags.contains_key("help")
    }

    pub fn help(&self, usage: &str) -> String {
        let mut s = format!("usage: {usage}\n\noptions:\n");
        for (n, d, h) in &self.spec {
            let dd = if d.is_empty() { String::new() } else { format!(" [default: {d}]") };
            s.push_str(&format!("  --{n:<18} {h}{dd}\n"));
        }
        s
    }

    fn raw(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    fn default_of(&self, key: &str) -> &str {
        self.spec
            .iter()
            .find(|(n, _, _)| n == key)
            .map(|(_, d, _)| d.as_str())
            .unwrap_or("")
    }

    pub fn get(&self, key: &str) -> String {
        self.raw(key).unwrap_or_else(|| self.default_of(key)).to_string()
    }

    pub fn get_opt(&self, key: &str) -> Option<String> {
        let v = self.get(key);
        if v.is_empty() {
            None
        } else {
            Some(v)
        }
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, CliError> {
        let v = self.get(key);
        v.parse()
            .map_err(|_| CliError::BadValue(key.into(), v, "usize"))
    }

    pub fn get_u64(&self, key: &str) -> Result<u64, CliError> {
        let v = self.get(key);
        v.parse().map_err(|_| CliError::BadValue(key.into(), v, "u64"))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, CliError> {
        let v = self.get(key);
        v.parse().map_err(|_| CliError::BadValue(key.into(), v, "f64"))
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key).as_str(), "true" | "1" | "yes")
    }

    /// Comma-separated list value.
    pub fn get_list(&self, key: &str) -> Vec<String> {
        self.get(key)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<(&'static str, &'static str, &'static str)> {
        vec![
            ("steps", "100", "training steps"),
            ("lr", "1e-4", "learning rate"),
            ("verbose", "false", "log more"),
            ("name", "", "run name"),
        ]
    }

    fn parse(args: &[&str]) -> Args {
        let raw: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        Args::parse(&raw, &spec()).unwrap()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parse(&["--steps", "5", "--lr=3e-4", "pos1"]);
        assert_eq!(a.get_usize("steps").unwrap(), 5);
        assert_eq!(a.get_f64("lr").unwrap(), 3e-4);
        assert!(!a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn bool_flag_without_value() {
        let a = parse(&["--verbose", "cmd"]);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["cmd"]);
    }

    #[test]
    fn unknown_flag_rejected() {
        let raw = vec!["--nope".to_string()];
        assert!(matches!(Args::parse(&raw, &spec()), Err(CliError::Unknown(_))));
    }

    #[test]
    fn missing_value_rejected() {
        let raw = vec!["--steps".to_string()];
        assert!(matches!(
            Args::parse(&raw, &spec()),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn last_wins_and_lists() {
        let a = parse(&["--name", "a", "--name", "b,c"]);
        assert_eq!(a.get("name"), "b,c");
        assert_eq!(a.get_list("name"), vec!["b", "c"]);
    }

    #[test]
    fn empty_default_is_none() {
        let a = parse(&[]);
        assert_eq!(a.get_opt("name"), None);
        assert!(a.get_opt("steps").is_some());
    }
}
