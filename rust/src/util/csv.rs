//! Minimal CSV reader (RFC-4180 quoting) for the report assembler that
//! turns `results/*.csv` back into tables.

#[derive(Debug, Clone, PartialEq)]
pub struct Csv {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

pub fn parse(text: &str) -> Csv {
    let mut records: Vec<Vec<String>> = Vec::new();
    let mut field = String::new();
    let mut record: Vec<String> = Vec::new();
    let mut in_quotes = false;
    let mut chars = text.chars().peekable();
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                _ => field.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => record.push(std::mem::take(&mut field)),
                '\r' => {}
                '\n' => {
                    record.push(std::mem::take(&mut field));
                    if !(record.len() == 1 && record[0].is_empty()) {
                        records.push(std::mem::take(&mut record));
                    } else {
                        record.clear();
                    }
                }
                _ => field.push(c),
            }
        }
    }
    if !field.is_empty() || !record.is_empty() {
        record.push(field);
        records.push(record);
    }
    let header = if records.is_empty() { vec![] } else { records.remove(0) };
    Csv { header, rows: records }
}

impl Csv {
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// Markdown rendering via the table printer.
    pub fn to_table(&self) -> crate::util::table::Table {
        let mut t = crate::util::table::Table::new(self.header.clone());
        for r in &self.rows {
            let mut row = r.clone();
            row.resize(self.header.len(), String::new());
            t.row(row);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_roundtrip() {
        let c = parse("a,b\n1,2\n3,4\n");
        assert_eq!(c.header, vec!["a", "b"]);
        assert_eq!(c.rows, vec![vec!["1", "2"], vec!["3", "4"]]);
        assert_eq!(c.col("b"), Some(1));
    }

    #[test]
    fn quoted_fields() {
        let c = parse("x,y\n\"a,b\",\"q\"\"z\"\n");
        assert_eq!(c.rows[0], vec!["a,b", "q\"z"]);
    }

    #[test]
    fn tolerates_missing_trailing_newline_and_crlf() {
        let c = parse("a,b\r\n1,2");
        assert_eq!(c.rows, vec![vec!["1", "2"]]);
    }

    #[test]
    fn empty_input() {
        let c = parse("");
        assert!(c.header.is_empty() && c.rows.is_empty());
    }
}
