//! Audited integer narrowing (the lisa-lint `int_cast` pass,
//! DESIGN.md §14).
//!
//! Page tables, decode row bookkeeping, and the int8 quantizer all
//! narrow machine-width values into the i32/u32/i8 the segment ABI
//! speaks. A bare `as` silently truncates on overflow; every such cast
//! on those paths routes through one of these helpers instead, which
//! pin the overflow behavior (saturate, never wrap) and concentrate the
//! justification in one reviewable file. lisa-lint flags any `as`
//! narrowing in the scoped files that bypasses this module.

/// usize position/index → the i32 the segment ABI carries (token ids,
/// row cursors, gather indices). Saturates at `i32::MAX`; sequence
/// lengths and row counts in this codebase are bounded by `seq`/`batch`
/// (≤ tens of thousands), so saturation is unreachable in practice and
/// a saturated value still fails loudly downstream (a gather at 2^31
/// is out of range for every table we build) rather than aliasing a
/// small index the way wrapping would.
#[inline]
pub fn idx_i32(v: usize) -> i32 {
    debug_assert!(v <= i32::MAX as usize, "index {v} overflows i32");
    v.min(i32::MAX as usize) as i32
}

/// usize count → u32 (page ids, pool sizes). Saturates at `u32::MAX`;
/// same bounded-domain argument as [`idx_i32`].
#[inline]
pub fn idx_u32(v: usize) -> u32 {
    debug_assert!(v <= u32::MAX as usize, "count {v} overflows u32");
    v.min(u32::MAX as usize) as u32
}

/// f32 → i8 for the int8 quantizer: clamps to the symmetric
/// quantization range [-127, 127] before the cast, so the `as` can
/// never saturate or wrap. The caller rounds first (`round_ties_even`);
/// any residual fraction truncates toward zero, matching the cast the
/// quantizer has always done. NaN follows Rust's float-to-int cast
/// semantics and maps to 0, the correct quantized value for a channel
/// the quantizer already rejected or zeroed.
#[inline]
pub fn sat_i8(v: f32) -> i8 {
    v.clamp(-127.0, 127.0) as i8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idx_i32_passes_small_and_saturates_large() {
        assert_eq!(idx_i32(0), 0);
        assert_eq!(idx_i32(4095), 4095);
        assert_eq!(idx_i32(i32::MAX as usize), i32::MAX);
        // release-mode saturation (the debug_assert fires under cfg(debug))
        if cfg!(not(debug_assertions)) {
            assert_eq!(idx_i32(usize::MAX), i32::MAX);
        }
    }

    #[test]
    fn idx_u32_passes_small_and_saturates_large() {
        assert_eq!(idx_u32(0), 0);
        assert_eq!(idx_u32(65_536), 65_536);
        assert_eq!(idx_u32(u32::MAX as usize), u32::MAX);
        if cfg!(not(debug_assertions)) {
            assert_eq!(idx_u32(usize::MAX), u32::MAX);
        }
    }

    #[test]
    fn sat_i8_clamps_to_the_symmetric_range() {
        assert_eq!(sat_i8(0.0), 0);
        assert_eq!(sat_i8(127.0), 127);
        assert_eq!(sat_i8(126.6), 126); // callers pre-round; residue truncates
        assert_eq!(sat_i8(500.0), 127);
        assert_eq!(sat_i8(-500.0), -127);
        assert_eq!(sat_i8(-128.0), -127); // -128 is outside the symmetric range
        assert_eq!(sat_i8(f32::NAN), 0);
    }
}
