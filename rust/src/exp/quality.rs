//! Quality experiments on the instruction-following task:
//!
//! * `suite-finetune` — one pass over {vanilla, FT, LoRA, GaLore, LISA}
//!   that regenerates Fig 1 (train loss), Fig 11 (val loss), Table 2
//!   (benchmark proxies), Table 3 (MT-Bench proxy), Table 8 (per-category)
//!   and the long-tail memorization probe (the Fig 5 substitution).
//! * `fig2-weightnorm` — LoRA-vs-FT layerwise weight-norm skew (Fig 2/12).
//! * `tab5-large` / `tab9-70b-cat` — the largest trainable config standing
//!   in for LLaMA-2-70B (scale substitution per DESIGN.md §4), plus the
//!   analytical 70B memory row.

use anyhow::Result;

use crate::data::corpus::CATEGORIES;
use crate::eval;
use crate::strategy::StrategySpec;
use crate::train::{TrainConfig, TrainSession};
use crate::util::table::{fnum, Table};

use super::common::{run_arm, sft_task, Ctx};

fn arm_specs(gamma: usize, k: usize, galore_rank: usize) -> Vec<StrategySpec> {
    vec![
        StrategySpec::vanilla(),
        StrategySpec::lora(),
        StrategySpec::galore(galore_rank).with("update-proj-gap", 50usize).with("scale", 1.0f32),
        StrategySpec::lisa(gamma, k),
        StrategySpec::ft(),
    ]
}

pub fn suite_finetune(ctx: &Ctx, config: &str) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let steps = ctx.steps(120);
    let mut task = sft_task(&rt, 480, 0.1, ctx.seed);
    log::info!(
        "suite-finetune[{config}]: {} train / {} val examples, {steps} steps",
        task.n_train,
        task.val.len()
    );

    let mut loss_series = Vec::new();
    let mut val_series = Vec::new();
    let mut tab2 = Table::new(vec![
        "Method", "Knowledge(MMLU-proxy)", "Reasoning(AGIEval-proxy)",
        "Extraction(WinoGrande-proxy)",
    ]);
    let mut tab3 =
        Table::new(vec!["Method", "MT-Bench-proxy", "val-loss", "val-ppl", "gen-EM"]);
    // generative decode slice: serving-path exact match per arm
    let (gen_samples, gen_max_new) =
        super::common::gen_slice(&task.val_samples, &task.tok, 24, rt.manifest.seq);
    let mut tab8 = Table::new({
        let mut h = vec!["Method".to_string()];
        h.extend(CATEGORIES.iter().map(|c| c.label().to_string()));
        h.push("Avg".into());
        h
    });
    let mut probe = Table::new(vec!["Method", "fact-recall-head", "fact-recall-tail"]);

    for spec in arm_specs(2, 10, rt.manifest.lora_rank.min(32)) {
        let cfg = TrainConfig {
            steps: if spec.is("vanilla") { 0 } else { steps },
            lr: spec.default_lr(),
            seed: ctx.seed,
            log_every: 25,
            ..Default::default()
        };
        let (res, mut sess) = run_arm(&rt, &spec, cfg, &mut task.train)?;
        let label = sess.label().to_string();
        let params = sess.eval_params();

        // curves (train loss EMA for readability, raw in CSV)
        loss_series.push((
            label.clone(),
            res.loss_curve.iter().map(|&(s, l)| (s, l as f64)).collect::<Vec<_>>(),
        ));
        let rep = eval::evaluate(&mut sess.engine, &params, &task.val)?;
        val_series.push((label.clone(), vec![(steps, rep.loss)]));

        let (cats, avg) = eval::category_scores(&mut sess.engine, &params, &task.val)?;
        let score = |c: crate::data::Category| cats.get(&c).copied().unwrap_or(0.0);
        use crate::data::Category as C;
        tab2.row(vec![
            label.clone(),
            fnum(10.0 * (score(C::Stem) + score(C::Humanities)) / 2.0, 2),
            fnum(10.0 * score(C::Reasoning), 2),
            fnum(10.0 * score(C::Extraction), 2),
        ]);
        let gen_em = eval::generative_exact_match(
            &mut sess.engine,
            &params,
            &task.tok,
            gen_samples,
            gen_max_new,
            ctx.sampler.clone(),
            ctx.gen_seed,
        )?;
        tab3.row(vec![
            label.clone(),
            fnum(avg, 2),
            fnum(rep.loss, 4),
            fnum(rep.ppl, 2),
            fnum(gen_em, 3),
        ]);
        let mut row = vec![label.clone()];
        row.extend(CATEGORIES.iter().map(|c| fnum(score(*c), 2)));
        row.push(fnum(avg, 2));
        tab8.row(row);

        let (head, tail) = eval::fact_recall(&mut sess.engine, &params, &task.tok)?;
        probe.row(vec![label.clone(), fnum(head, 3), fnum(tail, 3)]);
    }

    println!("\n## Table 2 (benchmark proxies, {config})\n");
    tab2.print();
    println!("\n## Table 3 (MT-Bench proxy, {config})\n");
    tab3.print();
    println!("\n## Table 8 (per-category MT-Bench proxy, {config})\n");
    tab8.print();
    println!("\n## Memorization probe (Fig 5 substitution)\n");
    probe.print();

    ctx.save_table(&format!("tab2-benchmarks-{config}"), &tab2)?;
    ctx.save_table(&format!("tab3-mtbench-{config}"), &tab3)?;
    ctx.save_table(&format!("tab8-mtbench-cat-{config}"), &tab8)?;
    ctx.save_table(&format!("fact-probe-{config}"), &probe)?;
    ctx.save_curve(&format!("fig1-loss-{config}"), &loss_series)?;
    ctx.save_curve(&format!("fig11-valloss-{config}"), &val_series)?;
    Ok(())
}

/// Fig 1 as its own id: the loss curves with periodic val loss (Fig 11).
pub fn fig1_loss(ctx: &Ctx, config: &str) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let steps = ctx.steps(120);
    let eval_every = (steps / 8).max(1);
    let mut task = sft_task(&rt, 480, 0.1, ctx.seed);
    let mut train_series = Vec::new();
    let mut val_series = Vec::new();
    for spec in arm_specs(2, 10, rt.manifest.lora_rank.min(32)) {
        if spec.is("vanilla") {
            continue;
        }
        let cfg = TrainConfig {
            steps: eval_every, // run in chunks so we can interleave val evals
            lr: spec.default_lr(),
            seed: ctx.seed,
            log_every: 0,
            ..Default::default()
        };
        let mut sess = TrainSession::new(&rt, &spec, cfg)?;
        let label = sess.label().to_string();
        let mut train_pts = Vec::new();
        let mut val_pts = Vec::new();
        let mut step = 0usize;
        while step < steps {
            let loss = sess.step(step, &mut task.train)?;
            train_pts.push((step, loss as f64));
            if step % eval_every == 0 || step + 1 == steps {
                let params = sess.eval_params();
                let (vl, _) = eval::eval_loss(&mut sess.engine, &params, &task.val)?;
                val_pts.push((step, vl));
            }
            step += 1;
        }
        log::info!("fig1 [{}] final train {:.4}", label, train_pts.last().unwrap().1);
        train_series.push((label.clone(), train_pts));
        val_series.push((label, val_pts));
    }
    ctx.save_curve(&format!("fig1-loss-{config}"), &train_series)?;
    ctx.save_curve(&format!("fig11-valloss-{config}"), &val_series)?;

    let mut t = Table::new(vec!["method", "first-loss", "final-train-loss", "final-val-loss"]);
    for ((label, tr), (_, va)) in train_series.iter().zip(&val_series) {
        t.row(vec![
            label.clone(),
            fnum(tr.first().unwrap().1, 4),
            fnum(tr.last().unwrap().1, 4),
            fnum(va.last().unwrap().1, 4),
        ]);
    }
    println!("\n## Fig 1 / Fig 11 (loss curves summary, {config}; full curves in results/)\n");
    t.print();
    Ok(())
}

/// Fig 2 / Fig 12: layerwise weight-norm skew of LoRA vs FT.
pub fn fig2_weightnorm(ctx: &Ctx, config: &str) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let steps = ctx.steps(60);
    let mut task = sft_task(&rt, 320, 0.1, ctx.seed);
    let mut series = Vec::new();
    let mut final_norms = Vec::new();
    let mut abs_norms: Vec<Vec<f64>> = Vec::new();
    for spec in [StrategySpec::lora(), StrategySpec::ft()] {
        let cfg = TrainConfig {
            steps,
            lr: spec.default_lr(),
            seed: ctx.seed,
            weight_norm_every: (steps / 10).max(1),
            log_every: 0,
            ..Default::default()
        };
        let (res, sess) = run_arm(&rt, &spec, cfg, &mut task.train)?;
        let label = sess.label().to_string();
        // Fig 2 plots the *update* emphasis: norm of (theta - theta_0) per
        // layer. Reconstruct delta norms from initial params.
        let init = crate::model::ModelParams::init(&rt.manifest, &mut crate::util::rng::Rng::new(ctx.seed));
        let cur = sess.eval_params();
        let delta_norm = |a: &crate::runtime::HostTensor, b: &crate::runtime::HostTensor| -> f64 {
            a.data.iter().zip(&b.data).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>().sqrt()
        };
        abs_norms.push(cur.layer_weight_norms());
        let mut deltas = vec![delta_norm(&cur.emb, &init.emb)];
        for (lc, li) in cur.blocks.iter().zip(&init.blocks) {
            let d: f64 = lc.iter().zip(li).map(|(a, b)| delta_norm(a, b).powi(2)).sum::<f64>().sqrt();
            deltas.push(d);
        }
        deltas.push(delta_norm(&cur.wh, &init.wh));
        final_norms.push((label.clone(), deltas));
        series.push((
            label,
            res.weight_norms
                .iter()
                .map(|(s, norms)| (*s, norms.iter().sum::<f64>()))
                .collect::<Vec<_>>(),
        ));
    }

    // The paper's Fig 2 observable is the absolute per-layer weight norm of
    // the trained model (embed/head dominate under LoRA); the update norm
    // ||dtheta|| exposes the mechanism (where each method concentrates change).
    let mut t = Table::new(vec![
        "layer", "lora-weight-norm", "ft-weight-norm",
        "lora-update-norm", "ft-update-norm", "lora/ft update",
    ]);
    let n = final_norms[0].1.len();
    for i in 0..n {
        let name = if i == 0 {
            "embed".to_string()
        } else if i == n - 1 {
            "head".to_string()
        } else {
            format!("block{}", i - 1)
        };
        let lo = final_norms[0].1[i];
        let ft = final_norms[1].1[i];
        t.row(vec![
            name,
            fnum(abs_norms[0][i], 3),
            fnum(abs_norms[1][i], 3),
            fnum(lo, 4),
            fnum(ft, 4),
            fnum(lo / ft.max(1e-9), 3),
        ]);
    }
    println!("\n## Fig 2 (layerwise update-norm skew: LoRA concentrates on embed/head)\n");
    t.print();
    ctx.save_table(&format!("fig2-weightnorm-{config}"), &t)?;
    ctx.save_curve(&format!("fig2-trajectory-{config}"), &series)?;
    Ok(())
}

/// Table 5 / Table 9: large-scale stand-in — the biggest trainable config
/// plus the analytical 70B memory row; γ=4 (paper's 70B setting).
pub fn tab5_large(ctx: &Ctx, config: &str) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let steps = ctx.steps(80);
    let mut sft = sft_task(&rt, 320, 0.15, ctx.seed);
    let mut math = super::common::math_task(&rt, 240, 120, ctx.seed);
    let mut med = super::common::medqa_task(&rt, 240, ctx.seed);

    let mut t = Table::new(vec![
        "Method", "MT-Bench-proxy", "GSM8K-proxy(EM%)", "PubMedQA-proxy(EM%)",
    ]);
    for spec in [
        StrategySpec::vanilla(),
        StrategySpec::lora(),
        StrategySpec::lisa(4, 10),
        StrategySpec::ft(),
    ] {
        let arm_steps = if spec.is("vanilla") { 0 } else { steps };
        let mk_cfg = |steps: usize, s: &StrategySpec| TrainConfig {
            steps,
            lr: s.default_lr(),
            seed: ctx.seed,
            log_every: 0,
            ..Default::default()
        };
        // instruction arm
        let (_r1, mut s1) = run_arm(&rt, &spec, mk_cfg(arm_steps, &spec), &mut sft.train)?;
        let label = s1.label().to_string();
        let p1 = s1.eval_params();
        let (_, mt) = eval::category_scores(&mut s1.engine, &p1, &sft.val)?;
        // math arm
        let (_r2, mut s2) = run_arm(&rt, &spec, mk_cfg(arm_steps, &spec), &mut math.train)?;
        let p2 = s2.eval_params();
        let gsm = eval::evaluate(&mut s2.engine, &p2, &math.test)?.exact_match;
        // medqa arm
        let (_r3, mut s3) = run_arm(&rt, &spec, mk_cfg(arm_steps, &spec), &mut med.train)?;
        let p3 = s3.eval_params();
        let pub_em = eval::evaluate(&mut s3.engine, &p3, &med.val)?.exact_match;

        t.row(vec![
            label,
            fnum(mt, 2),
            fnum(100.0 * gsm, 1),
            fnum(100.0 * pub_em, 1),
        ]);
    }
    println!("\n## Table 5 (large-scale stand-in on '{config}'; 70B memory row is analytical — see tab1-memory)\n");
    t.print();
    ctx.save_table(&format!("tab5-large-{config}"), &t)?;
    Ok(())
}
