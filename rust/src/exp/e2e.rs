//! End-to-end driver: the full three-layer system on one real workload —
//! AOT artifacts → Rust engine → LISA schedule → eval → checkpoint.
//! This is the run recorded in EXPERIMENTS.md §End-to-End.

use anyhow::Result;

use crate::eval;
use crate::model::checkpoint;
use crate::strategy::StrategySpec;
use crate::train::{TrainConfig, TrainSession};
use crate::util::table::{fnum, human_bytes, Table};

use super::common::{sft_task, Ctx};

pub fn e2e(ctx: &Ctx, config: &str, steps_override: Option<usize>) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let m = &rt.manifest;
    let steps = steps_override.unwrap_or_else(|| ctx.steps(200));
    let eval_every = (steps / 5).max(1);
    log::info!(
        "e2e: config={config} ({:.1}M params, d={}, L={}, T={}, B={}), {} steps, LISA γ=2 K=10",
        m.n_params as f64 / 1e6,
        m.d_model,
        m.n_layers,
        m.seq,
        m.batch,
        steps
    );

    let mut task = sft_task(&rt, 640, 0.04, ctx.seed);
    let spec = StrategySpec::lisa(2, 10);
    // cfg.steps carries the *real* horizon (the driver steps manually):
    // the default Warmup schedule ignores it, and checkpoints store it so
    // resume can validate its position against the run length.
    let cfg = TrainConfig {
        steps,
        lr: 3e-3,
        seed: ctx.seed,
        log_every: 0,
        ..Default::default()
    };
    let mut sess = TrainSession::new(&rt, &spec, cfg)?;

    // Crash-safe mode: periodic full-state checkpoints + resume (the
    // preemptible-workload story — DESIGN.md §7).
    super::common::ensure_dir(&ctx.results)?;
    let state_path = ctx.results.join(format!("e2e-{config}.state"));
    let start = match &ctx.resume {
        Some(path) => {
            let next = sess.resume_checkpoint(path, &mut task.train)?;
            log::info!("e2e: resumed from {} at step {next}/{steps}", path.display());
            next
        }
        None => 0,
    };

    let t0 = std::time::Instant::now();
    let mut curve: Vec<(usize, f64)> = Vec::new();
    let mut val_curve: Vec<(usize, f64)> = Vec::new();
    let mut step_times = Vec::new();
    for step in start..steps {
        let ts = std::time::Instant::now();
        let loss = sess.step(step, &mut task.train)?;
        step_times.push(ts.elapsed().as_secs_f64() * 1e3);
        curve.push((step, loss as f64));
        if ctx.save_every > 0 && (step + 1) % ctx.save_every == 0 {
            sess.save_checkpoint(&state_path, step + 1, &task.train)?;
        }
        if step % eval_every == 0 || step + 1 == steps {
            let params = sess.eval_params();
            let (vl, _) = eval::eval_loss(&mut sess.engine, &params, &task.val)?;
            val_curve.push((step, vl));
            log::info!(
                "e2e step {step}/{steps}: train {loss:.4} val {vl:.4} ({:.0} ms/step)",
                crate::util::stats::median(&step_times)
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let params = sess.eval_params();
    let rep = eval::evaluate(&mut sess.engine, &params, &task.val)?;
    let (cats, mt) = eval::category_scores(&mut sess.engine, &params, &task.val)?;

    // Serving-shaped metric: batched KV-cached greedy decode over a val
    // slice (falls back to the legacy full-forward path for artifact dirs
    // without the decode ABI, or under LISA_DECODE=legacy).
    let cached_decode = eval::generate::uses_cached_decode(&sess.engine);
    let (gen_samples, gen_max_new) =
        super::common::gen_slice(&task.val_samples, &task.tok, 32, m.seq);
    // snapshot the *training* memory observable before the decode session
    // meters its own (serving) activation peak on the same engine
    let train_peak = sess.engine.meter.peak();
    let tg = std::time::Instant::now();
    let (gen_em, gen_completions) = eval::generative_completions(
        &mut sess.engine,
        &params,
        &task.tok,
        gen_samples,
        gen_max_new,
        ctx.sampler.clone(),
        ctx.gen_seed,
    )?;
    let gen_ms = tg.elapsed().as_secs_f64() * 1e3;
    let tokens_per_step = (m.batch * m.seq) as f64;
    let med_ms = crate::util::stats::median(&step_times);

    super::common::ensure_dir(&ctx.results)?;
    let ckpt = ctx.results.join(format!("e2e-{config}.ckpt"));
    checkpoint::save_model(&ckpt, &sess.params)?;

    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["config".to_string(), format!("{config} ({:.1}M params)", m.n_params as f64 / 1e6)]);
    t.row(vec!["steps".to_string(), steps.to_string()]);
    t.row(vec!["wall clock".to_string(), format!("{wall:.1} s")]);
    t.row(vec!["median step".to_string(), format!("{med_ms:.0} ms")]);
    let throughput = if med_ms > 0.0 {
        format!("{:.0} tok/s", tokens_per_step / (med_ms / 1e3))
    } else {
        "-".to_string() // fully-resumed run: no steps executed
    };
    t.row(vec!["throughput".to_string(), throughput]);
    // a fully-resumed run can execute zero steps: the curves are then empty
    let first_or = |c: &Vec<(usize, f64)>| c.first().map(|p| p.1).unwrap_or(f64::NAN);
    let last_or = |c: &Vec<(usize, f64)>| c.last().map(|p| p.1).unwrap_or(f64::NAN);
    t.row(vec!["first train loss".to_string(), fnum(first_or(&curve), 4)]);
    t.row(vec!["final train loss".to_string(), fnum(last_or(&curve), 4)]);
    t.row(vec!["final val loss".to_string(), fnum(last_or(&val_curve), 4)]);
    t.row(vec!["val ppl".to_string(), fnum(rep.ppl, 2)]);
    t.row(vec!["val token acc".to_string(), fnum(rep.token_acc, 3)]);
    t.row(vec!["val exact match".to_string(), fnum(rep.exact_match, 3)]);
    t.row(vec![
        "gen exact match".to_string(),
        format!("{} ({} samples, {gen_ms:.0} ms)", fnum(gen_em, 3), gen_samples.len()),
    ]);
    t.row(vec![
        "decode path".to_string(),
        if cached_decode {
            "KV-cached, continuous batching".to_string()
        } else {
            "legacy full-forward".to_string()
        },
    ]);
    t.row(vec![
        "decode sampler".to_string(),
        format!("{} (gen-seed {})", ctx.sampler.label(), ctx.gen_seed),
    ]);
    t.row(vec!["MT-Bench proxy".to_string(), fnum(mt, 2)]);
    t.row(vec!["peak tracked mem".to_string(), human_bytes(train_peak)]);
    t.row(vec![
        "peak tracked mem (incl. decode)".to_string(),
        human_bytes(sess.engine.meter.peak()),
    ]);
    let cs = sess.engine.device_cache_stats();
    t.row(vec![
        "device cache".to_string(),
        format!(
            "{} bufs, {} resident; {} hits / {} uploads",
            cs.entries,
            human_bytes(cs.resident_bytes),
            cs.hits,
            cs.misses
        ),
    ]);
    t.row(vec!["checkpoint".to_string(), ckpt.display().to_string()]);
    println!("\n## End-to-end run ({config})\n");
    t.print();
    println!("\nper-category proxy scores:");
    for (c, s) in &cats {
        println!("  {:<12} {s:.2}", c.label());
    }
    println!("\nqualitative samples (greedy decode):");
    for (s, c) in gen_samples.iter().zip(&gen_completions).take(3) {
        println!("  {} -> {}", s.prompt, task.tok.decode(&c.tokens));
    }

    ctx.save_table(&format!("e2e-{config}"), &t)?;
    ctx.save_curve(
        &format!("e2e-loss-{config}"),
        &[("train".to_string(), curve), ("val".to_string(), val_curve)],
    )?;

    // Per-segment runtime profile (the L3 §Perf input). Upload columns
    // surface the device-residency win: cached weights and chained
    // activations show up as device-served operands, not uploads.
    let mut prof = Table::new(vec![
        "segment", "calls", "total s", "mean ms", "uploads", "upload MB", "dev-served",
    ]);
    let mut stats: Vec<_> = rt.stats().into_iter().collect();
    stats.sort_by(|a, b| b.1.total_ns.cmp(&a.1.total_ns));
    for (name, s) in stats {
        prof.row(vec![
            name,
            s.calls.to_string(),
            fnum(s.total_ns as f64 / 1e9, 2),
            fnum(s.total_ns as f64 / 1e6 / s.calls.max(1) as f64, 1),
            s.uploads.to_string(),
            fnum(s.upload_bytes as f64 / 1e6, 1),
            s.buf_hits.to_string(),
        ]);
    }
    println!("\nper-segment profile:");
    prof.print();
    ctx.save_table(&format!("e2e-profile-{config}"), &prof)?;
    Ok(())
}
