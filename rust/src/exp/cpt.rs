//! Continual pre-training experiments (paper §4.3, Appendix A.3):
//! Table 4 (CPT → GSM8K-proxy accuracy + memory) and Fig 7 (γ sweep).
//!
//! Pipeline: continual-pretrain on the arithmetic-document corpus (plain
//! LM loss), checkpoint, fine-tune on the word-problem train split, then
//! exact-match on held-out problems — structurally identical to the
//! paper's OpenWebMath → GSM8K pipeline.

use anyhow::Result;

use crate::eval;
use crate::strategy::StrategySpec;
use crate::train::{TrainConfig, TrainSession};
use crate::util::table::{fnum, human_bytes, Table};

use super::common::{ensure_dir, math_task, run_arm_ckpt, Ctx};

/// One CPT→FT pipeline run; returns (EM accuracy, peak CPT memory bytes).
fn pipeline(
    ctx: &Ctx,
    rt: &crate::runtime::Runtime,
    task: &mut super::common::MathTask,
    spec: &StrategySpec,
    cpt_steps: usize,
    ft_steps: usize,
) -> Result<(f64, u64)> {
    // Stage 1: continual pre-training (skipped for Vanilla). With
    // `--save-every N` the stage checkpoints its full training state and a
    // restarted `lisa exp` resumes instead of repeating finished work —
    // CPT is the long preemptible leg of this pipeline.
    let (params, cpt_peak) = if spec.is("vanilla") {
        let mut rng = crate::util::rng::Rng::new(ctx.seed);
        (crate::model::ModelParams::init(&rt.manifest, &mut rng), 0u64)
    } else {
        let cfg = TrainConfig {
            steps: cpt_steps,
            lr: spec.default_lr(),
            seed: ctx.seed,
            log_every: 0,
            ..Default::default()
        };
        // distinct state file per arm configuration (fig7 sweeps γ with
        // the same method name; resuming across configs must not collide)
        let mut slug = spec.name.clone();
        for key in ["gamma", "period", "rank"] {
            if let Some(v) = spec.opts.get(key) {
                slug.push_str(&format!("-{key}{v}"));
            }
        }
        // steps and seed are config axes too: resuming a different sweep
        // point must miss, not hard-error on the seed check
        slug.push_str(&format!("-s{cpt_steps}-seed{}", ctx.seed));
        let state_path = (ctx.save_every > 0)
            .then(|| ctx.results.join(format!("cpt-{slug}-{}.state", rt.manifest.name)));
        if state_path.is_some() {
            ensure_dir(&ctx.results)?;
        }
        let state = state_path.as_deref().map(|p| (p, ctx.save_every));
        let (res, sess) = run_arm_ckpt(rt, spec, cfg, &mut task.cpt, state)?;
        (sess.eval_params(), res.peak_mem)
    };

    // Stage 2: supervised fine-tune on word problems (same method; the
    // paper fine-tunes with the same procedure after CPT).
    let ft_spec = if spec.is("vanilla") { StrategySpec::ft() } else { spec.clone() };
    let cfg = TrainConfig {
        steps: ft_steps,
        lr: ft_spec.default_lr(),
        seed: ctx.seed ^ 0xf7,
        log_every: 0,
        ..Default::default()
    };
    let mut sess = TrainSession::with_params(rt, &ft_spec, cfg, params)?;
    sess.run(&mut task.train)?;
    let p = sess.eval_params();
    let em = eval::evaluate(&mut sess.engine, &p, &task.test)?.exact_match;
    Ok((em, cpt_peak))
}

/// Table 4: Vanilla / LISA / FT continual pre-training.
pub fn tab4_cpt(ctx: &Ctx, config: &str) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let cpt_steps = ctx.steps(60);
    let ft_steps = ctx.steps(40);
    let mut task = math_task(&rt, 400, 240, ctx.seed);
    let gamma = (rt.manifest.n_layers / 2).max(1); // "half the layers" rule

    let mut t = Table::new(vec!["Method", "GSM8K-proxy(EM%)", "CPT peak mem"]);
    for spec in [
        StrategySpec::vanilla(),
        StrategySpec::lisa(gamma, (cpt_steps / 6).max(1)),
        StrategySpec::ft(),
    ] {
        let label = spec.name.clone();
        let (em, peak) = pipeline(ctx, &rt, &mut task, &spec, cpt_steps, ft_steps)?;
        t.row(vec![
            label,
            fnum(100.0 * em, 1),
            if peak == 0 { "-".into() } else { human_bytes(peak) },
        ]);
    }
    println!("\n## Table 4 (continual pre-training on '{config}', γ=L/2)\n");
    t.print();
    ctx.save_table(&format!("tab4-cpt-{config}"), &t)?;
    Ok(())
}

/// Fig 7 / Appendix A.3: CPT accuracy across γ ∈ {2,4,8,16} vs FT.
pub fn fig7_cpt_gamma(ctx: &Ctx, config: &str) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let cpt_steps = ctx.steps(50);
    let ft_steps = ctx.steps(30);
    let mut task = math_task(&rt, 400, 240, ctx.seed);
    let n_layers = rt.manifest.n_layers;

    let mut t = Table::new(vec!["arm", "GSM8K-proxy(EM%)"]);
    for gamma in [2usize, 4, 8, 16] {
        if gamma > n_layers {
            continue;
        }
        let spec = StrategySpec::lisa(gamma, (cpt_steps / 6).max(1));
        let (em, _) = pipeline(ctx, &rt, &mut task, &spec, cpt_steps, ft_steps)?;
        t.row(vec![format!("LISA γ={gamma}"), fnum(100.0 * em, 1)]);
    }
    let (em_ft, _) = pipeline(ctx, &rt, &mut task, &StrategySpec::ft(), cpt_steps, ft_steps)?;
    t.row(vec!["FT".to_string(), fnum(100.0 * em_ft, 1)]);

    println!("\n## Fig 7 (CPT γ sweep on '{config}')\n");
    t.print();
    ctx.save_table(&format!("fig7-cpt-gamma-{config}"), &t)?;
    Ok(())
}
