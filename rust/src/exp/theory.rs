//! Theorem-1 verification (`exp theory-convergence`): the paper proves
//! (via RBC-Adam, Zhou et al. 2020) that LISA's layerwise-sampled AdamW
//! converges at O(1/sqrt(T)) average regret on convex objectives.
//!
//! We verify empirically on a blockwise convex quadratic
//! `f(w) = Σ_ℓ ||A_ℓ w_ℓ − b_ℓ||²/2` — the "layers" are coordinate blocks,
//! LISA updates only the sampled blocks each period — and check that the
//! running average of `f^reg(w_t) − f*` decays like c/sqrt(t): the fitted
//! log-log slope must be ≤ ~−0.5 and the sequence monotone after burn-in.

use anyhow::Result;

use crate::model::ParamKey;
use crate::opt::{adamw::AdamHp, AdamW, StatePolicy};
use crate::util::rng::Rng;
use crate::util::table::{fnum, Table};

use super::common::Ctx;

struct BlockQuadratic {
    /// per block: (a diag, b) so f_ℓ(w) = Σ_i (a_i w_i − b_i)²/2
    blocks: Vec<(Vec<f32>, Vec<f32>)>,
}

impl BlockQuadratic {
    fn new(n_blocks: usize, dim: usize, rng: &mut Rng) -> Self {
        let blocks = (0..n_blocks)
            .map(|_| {
                let a: Vec<f32> = (0..dim).map(|_| 0.5 + rng.f32() * 2.0).collect();
                let b: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
                (a, b)
            })
            .collect();
        BlockQuadratic { blocks }
    }

    fn loss(&self, w: &[Vec<f32>]) -> f64 {
        self.blocks
            .iter()
            .zip(w)
            .map(|((a, b), wl)| {
                wl.iter()
                    .zip(a.iter().zip(b))
                    .map(|(&x, (&ai, &bi))| {
                        let r = (ai * x - bi) as f64;
                        r * r / 2.0
                    })
                    .sum::<f64>()
            })
            .sum()
    }

    fn grad_block(&self, l: usize, wl: &[f32]) -> Vec<f32> {
        let (a, b) = &self.blocks[l];
        wl.iter()
            .zip(a.iter().zip(b))
            .map(|(&x, (&ai, &bi))| ai * (ai * x - bi))
            .collect()
    }

    /// Analytic minimum: w* = b/a per coordinate, f* = 0.
    fn optimum(&self) -> f64 {
        0.0
    }
}

/// Run LISA-AdamW on the blockwise quadratic; returns averaged suboptimality
/// at checkpoints (t, avg_regret).
fn run_lisa_quadratic(
    n_blocks: usize,
    dim: usize,
    gamma: usize,
    period: usize,
    steps: usize,
    seed: u64,
) -> Vec<(usize, f64)> {
    let mut rng = Rng::new(seed);
    let prob = BlockQuadratic::new(n_blocks, dim, &mut rng);
    let mut w: Vec<Vec<f32>> = (0..n_blocks).map(|_| vec![0.0; dim]).collect();
    let mut opt = AdamW::new(
        AdamHp { lr: 0.05, weight_decay: 0.0, ..Default::default() },
        StatePolicy::Keep,
    );
    let mut sampler = crate::lisa::LisaScheduler::new(
        crate::lisa::LisaConfig {
            gamma,
            period_k: period,
            train_embed: false,
            train_head: false,
            dist: crate::lisa::LayerDist::Uniform,
            fixed: false,
        },
        n_blocks,
        seed ^ 0x7e0,
    );
    let fstar = prob.optimum();
    let mut cum = 0.0f64;
    let mut out = Vec::new();
    for t in 0..steps {
        let mask = sampler.mask_for_step(t);
        for (l, &on) in mask.blocks.iter().enumerate() {
            if !on {
                continue;
            }
            let g = prob.grad_block(l, &w[l]);
            opt.step(ParamKey::Block(l, 0), false, &mut w[l], &g);
        }
        cum += prob.loss(&w) - fstar;
        if (t + 1).is_power_of_two() || t + 1 == steps {
            out.push((t + 1, cum / (t + 1) as f64));
        }
    }
    out
}

/// Least-squares slope of log(avg_regret) vs log(t) over the tail.
pub fn loglog_slope(pts: &[(usize, f64)]) -> f64 {
    let tail: Vec<(f64, f64)> = pts
        .iter()
        .filter(|(t, v)| *t >= 8 && *v > 0.0)
        .map(|(t, v)| ((*t as f64).ln(), v.ln()))
        .collect();
    let n = tail.len() as f64;
    let sx: f64 = tail.iter().map(|(x, _)| x).sum();
    let sy: f64 = tail.iter().map(|(_, y)| y).sum();
    let sxx: f64 = tail.iter().map(|(x, _)| x * x).sum();
    let sxy: f64 = tail.iter().map(|(x, y)| x * y).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

pub fn theory_convergence(ctx: &Ctx, _config: &str) -> Result<()> {
    let mut t = Table::new(vec![
        "setting", "avg regret @T/4", "avg regret @T", "log-log slope",
    ]);
    let steps = 4096;
    for (label, gamma, period) in [
        ("LISA γ=2/8 K=5", 2usize, 5usize),
        ("LISA γ=4/8 K=5", 4, 5),
        ("LISA γ=8/8 (full Adam)", 8, 5),
        ("LISA γ=2/8 K=1", 2, 1),
    ] {
        let pts = run_lisa_quadratic(8, 16, gamma, period, steps, ctx.seed);
        let slope = loglog_slope(&pts);
        let at = |t: usize| {
            pts.iter()
                .min_by_key(|(x, _)| x.abs_diff(t))
                .map(|(_, v)| *v)
                .unwrap_or(f64::NAN)
        };
        t.row(vec![
            label.to_string(),
            fnum(at(steps / 4), 5),
            fnum(at(steps), 5),
            fnum(slope, 3),
        ]);
    }
    println!("\n## Theorem 1 check: averaged suboptimality decays ~ O(1/sqrt(T)) (slope <= -0.5)\n");
    t.print();
    ctx.save_table("theory-convergence", &t)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lisa_quadratic_converges_with_sublinear_regret() {
        let pts = run_lisa_quadratic(6, 8, 2, 4, 2048, 3);
        let last = pts.last().unwrap().1;
        let first = pts.first().unwrap().1;
        assert!(last < first, "avg regret must decrease: {first} -> {last}");
        let slope = loglog_slope(&pts);
        assert!(slope < -0.4, "expected ~-0.5 or faster, got {slope}");
    }

    #[test]
    fn full_adam_no_slower_than_sampled() {
        let sampled = run_lisa_quadratic(6, 8, 2, 4, 1024, 7).last().unwrap().1;
        let full = run_lisa_quadratic(6, 8, 6, 4, 1024, 7).last().unwrap().1;
        assert!(full <= sampled * 1.2, "full {full} vs sampled {sampled}");
    }

    #[test]
    fn slope_fit_on_known_powerlaw() {
        let pts: Vec<(usize, f64)> = (1..12).map(|i| {
            let t = 1usize << i;
            (t, 3.0 / (t as f64).sqrt())
        }).collect();
        let s = loglog_slope(&pts);
        assert!((s + 0.5).abs() < 1e-6, "slope {s}");
    }
}
