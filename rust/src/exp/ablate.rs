//! Ablations: Table 6 (γ × K), Table 7 (seeds), Table 10 (γ × lr),
//! Table 11 (LISA-fix), Figs 8/9/10 (the corresponding loss curves), and
//! the extensions: weighted importance sampling (Limitations §) and
//! gradient-adaptive sampling (`lisa-grad`, the GRASS direction).

use anyhow::Result;

use crate::eval;
use crate::strategy::StrategySpec;
use crate::train::TrainConfig;
use crate::util::table::{fnum, Table};

use super::common::{math_task, run_arm, sft_task, Ctx};

/// Table 6 + Figs 8/9: γ ∈ {2, 8} × K ∈ {T, T/5, T/10, 1}.
pub fn tab6_hparams(ctx: &Ctx, config: &str) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let steps = ctx.steps(60);
    let mut task = sft_task(&rt, 320, 0.12, ctx.seed);
    let n_layers = rt.manifest.n_layers;

    let mut t = Table::new(vec!["gamma", "K", "MT-Bench-proxy", "final-train-loss"]);
    let mut gamma_curves = Vec::new();
    let mut k_curves = Vec::new();
    for gamma in [2usize, n_layers.min(8).max(3)] {
        for k in [steps, (steps / 5).max(1), (steps / 10).max(1), 1] {
            let spec = StrategySpec::lisa(gamma, k);
            let cfg = TrainConfig { steps, lr: 3e-3, seed: ctx.seed, log_every: 0, ..Default::default() };
            let (res, mut sess) = run_arm(&rt, &spec, cfg, &mut task.train)?;
            let params = sess.eval_params();
            let (_, score) = eval::category_scores(&mut sess.engine, &params, &task.val)?;
            t.row(vec![
                gamma.to_string(),
                k.to_string(),
                fnum(score, 2),
                fnum(res.final_train_loss as f64, 4),
            ]);
            let curve: Vec<(usize, f64)> =
                res.loss_curve.iter().map(|&(s, l)| (s, l as f64)).collect();
            if k == (steps / 10).max(1) {
                gamma_curves.push((format!("gamma={gamma}"), curve.clone()));
            }
            if gamma == 2 {
                k_curves.push((format!("K={k}"), curve));
            }
        }
    }
    println!("\n## Table 6 (LISA hyperparameters γ × K on '{config}')\n");
    t.print();
    ctx.save_table(&format!("tab6-hparams-{config}"), &t)?;
    ctx.save_curve(&format!("fig8-gamma-loss-{config}"), &gamma_curves)?;
    ctx.save_curve(&format!("fig9-periodK-{config}"), &k_curves)?;
    Ok(())
}

/// Table 7 + Fig 10: seed sensitivity of the layer sampler.
pub fn tab7_seeds(ctx: &Ctx, config: &str) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let steps = ctx.steps(60);
    let mut task = sft_task(&rt, 320, 0.12, ctx.seed);
    let mut t = Table::new(vec!["seed", "MT-Bench-proxy", "final-train-loss"]);
    let mut curves = Vec::new();
    let mut scores = Vec::new();
    for (i, seed) in [1u64, 2, 3].into_iter().enumerate() {
        let cfg = TrainConfig { steps, lr: 3e-3, seed, log_every: 0, ..Default::default() };
        let spec = StrategySpec::lisa(2, (steps / 5).max(1));
        let (res, mut sess) = run_arm(&rt, &spec, cfg, &mut task.train)?;
        let params = sess.eval_params();
        let (_, score) = eval::category_scores(&mut sess.engine, &params, &task.val)?;
        scores.push(score);
        t.row(vec![
            format!("seed {}", i + 1),
            fnum(score, 2),
            fnum(res.final_train_loss as f64, 4),
        ]);
        curves.push((
            format!("seed{}", i + 1),
            res.loss_curve.iter().map(|&(s, l)| (s, l as f64)).collect(),
        ));
    }
    let spread = scores.iter().cloned().fold(f64::MIN, f64::max)
        - scores.iter().cloned().fold(f64::MAX, f64::min);
    println!("\n## Table 7 (seed sensitivity on '{config}'; spread = {spread:.3})\n");
    t.print();
    ctx.save_table(&format!("tab7-seeds-{config}"), &t)?;
    ctx.save_curve(&format!("fig10-randomness-{config}"), &curves)?;
    Ok(())
}

/// Table 10: γ × learning-rate grid on the GSM8K proxy.
pub fn tab10_gamma_lr(ctx: &Ctx, config: &str) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let steps = ctx.steps(50);
    let mut task = math_task(&rt, 320, 160, ctx.seed);
    let n_layers = rt.manifest.n_layers;
    let gammas: Vec<usize> = [2usize, 4, 8, 16]
        .into_iter()
        .filter(|&g| g <= n_layers)
        .collect();
    let lrs = [5e-3f32, 2.5e-3, 1.25e-3, 6.25e-4];

    let mut t = Table::new({
        let mut h = vec!["gamma".to_string()];
        h.extend(lrs.iter().map(|l| format!("lr={l:.2e}")));
        h
    });
    for &gamma in &gammas {
        let mut row = vec![gamma.to_string()];
        for &lr in &lrs {
            let cfg = TrainConfig { steps, lr, seed: ctx.seed, log_every: 0, ..Default::default() };
            let spec = StrategySpec::lisa(gamma, (steps / 5).max(1));
            let (_res, mut sess) = run_arm(&rt, &spec, cfg, &mut task.train)?;
            let params = sess.eval_params();
            let em = eval::evaluate(&mut sess.engine, &params, &task.test)?.exact_match;
            row.push(fnum(100.0 * em, 1));
        }
        t.row(row);
    }
    println!("\n## Table 10 (γ × η grid, GSM8K-proxy EM% on '{config}')\n");
    t.print();
    ctx.save_table(&format!("tab10-gamma-lr-{config}"), &t)?;
    Ok(())
}

/// Table 11: resampling LISA vs fixed random layer subsets.
pub fn tab11_fixed(ctx: &Ctx, config: &str) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let steps = ctx.steps(60);
    let mut task = sft_task(&rt, 320, 0.12, ctx.seed);
    let mut t = Table::new(vec!["Method", "MT-Bench-proxy", "final-train-loss"]);
    let k = (steps / 5).max(1);
    let mut arms: Vec<(String, StrategySpec, u64)> =
        vec![("LISA".into(), StrategySpec::lisa(2, k), ctx.seed)];
    for i in 1..=3u64 {
        arms.push((format!("LISA-fix-{i}"), StrategySpec::lisa_fixed(2, k), i));
    }
    for (label, spec, seed) in arms {
        let cfg = TrainConfig { steps, lr: 3e-3, seed, log_every: 0, ..Default::default() };
        let (res, mut sess) = run_arm(&rt, &spec, cfg, &mut task.train)?;
        let params = sess.eval_params();
        let (_, score) = eval::category_scores(&mut sess.engine, &params, &task.val)?;
        t.row(vec![label, fnum(score, 2), fnum(res.final_train_loss as f64, 4)]);
    }
    println!("\n## Table 11 (LISA vs fixed layer subsets on '{config}')\n");
    t.print();
    ctx.save_table(&format!("tab11-fixed-{config}"), &t)?;
    Ok(())
}

/// Extension (paper Limitations §): non-uniform importance sampling driven
/// by the measured LoRA/FT weight-norm ratio vs uniform LISA.
pub fn lisa_weighted(ctx: &Ctx, config: &str) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let steps = ctx.steps(60);
    let mut task = sft_task(&rt, 320, 0.12, ctx.seed);
    let n_layers = rt.manifest.n_layers;
    let k = (steps / 5).max(1);

    // U-shaped importance: layers near the ends matter more (the paper's
    // observed skew); middle layers get lower probability.
    let weights: Vec<f64> = (0..n_layers)
        .map(|l| {
            let x = l as f64 / (n_layers - 1).max(1) as f64;
            0.25 + (2.0 * x - 1.0).powi(2)
        })
        .collect();

    let mut t = Table::new(vec!["variant", "MT-Bench-proxy", "final-train-loss"]);
    let arms: Vec<(&str, StrategySpec)> = vec![
        ("uniform", StrategySpec::lisa(2, k)),
        ("weighted(U-shape)", StrategySpec::lisa_weighted(2, k, &weights)),
    ];
    for (label, spec) in arms {
        let cfg = TrainConfig { steps, lr: 3e-3, seed: ctx.seed, log_every: 0, ..Default::default() };
        let (res, mut sess) = run_arm(&rt, &spec, cfg, &mut task.train)?;
        let params = sess.eval_params();
        let (_, score) = eval::category_scores(&mut sess.engine, &params, &task.val)?;
        t.row(vec![label.to_string(), fnum(score, 2), fnum(res.final_train_loss as f64, 4)]);
    }
    println!("\n## Extension: uniform vs importance-weighted layer sampling ('{config}')\n");
    t.print();
    ctx.save_table(&format!("lisa-weighted-{config}"), &t)?;
    Ok(())
}

/// Extension (GRASS direction, PAPERS.md): gradient-adaptive importance
/// sampling — each resample weights blocks by a running EMA of their
/// gradient norms — vs the paper's uniform LISA and full fine-tuning. This
/// arm exists purely through the strategy registry: no training-loop code
/// knows about it.
pub fn lisa_grad(ctx: &Ctx, config: &str) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let steps = ctx.steps(60);
    let mut task = sft_task(&rt, 320, 0.12, ctx.seed);
    let k = (steps / 5).max(1);

    let mut t = Table::new(vec!["Method", "MT-Bench-proxy", "final-train-loss"]);
    for spec in [
        StrategySpec::lisa(2, k),
        StrategySpec::lisa_grad(2, k),
        StrategySpec::ft(),
    ] {
        let cfg = TrainConfig {
            steps,
            lr: spec.default_lr(),
            seed: ctx.seed,
            log_every: 0,
            ..Default::default()
        };
        let (res, mut sess) = run_arm(&rt, &spec, cfg, &mut task.train)?;
        let label = sess.label().to_string();
        let params = sess.eval_params();
        let (_, score) = eval::category_scores(&mut sess.engine, &params, &task.val)?;
        t.row(vec![label, fnum(score, 2), fnum(res.final_train_loss as f64, 4)]);
    }
    println!("\n## Extension: gradient-adaptive importance sampling ('{config}')\n");
    t.print();
    ctx.save_table(&format!("lisa-grad-{config}"), &t)?;
    Ok(())
}
