//! Table 12: LISA × early-exit (DoLa-style) evaluation — exact-match on
//! the GSM8K-proxy when logits are taken from intermediate depths.

use anyhow::Result;

use crate::eval;
use crate::strategy::StrategySpec;
use crate::train::{TrainConfig, TrainSession};
use crate::util::table::{fnum, Table};

use super::common::{math_task, Ctx};

pub fn tab12_dola(ctx: &Ctx, config: &str) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let steps = ctx.steps(60);
    let mut task = math_task(&rt, 320, 160, ctx.seed);
    let n_layers = rt.manifest.n_layers;
    let depths = [n_layers / 4, n_layers / 2, (3 * n_layers) / 4, n_layers];

    let mut t = Table::new({
        let mut h = vec!["method".to_string()];
        h.extend(depths.iter().map(|d| format!("exit@{d}/{n_layers} EM%")));
        h
    });

    let arms: Vec<(String, Option<StrategySpec>)> = vec![
        ("vanilla".into(), None),
        ("ft".into(), Some(StrategySpec::ft())),
        ("lisa".into(), Some(StrategySpec::lisa(2, (steps / 5).max(1)))),
    ];
    for (label, spec) in arms {
        let mut sess = match spec {
            None => TrainSession::new(
                &rt,
                &StrategySpec::vanilla(),
                TrainConfig { steps: 0, log_every: 0, ..Default::default() },
            )?,
            Some(spec) => {
                let cfg = TrainConfig {
                    steps,
                    lr: spec.default_lr(),
                    seed: ctx.seed,
                    log_every: 0,
                    ..Default::default()
                };
                let mut s = TrainSession::new(&rt, &spec, cfg)?;
                s.run(&mut task.train)?;
                s
            }
        };
        let params = sess.eval_params();
        let mut row = vec![label];
        for &d in &depths {
            let em = eval::exact_match_at_depth(&mut sess.engine, &params, &task.test, d)?;
            row.push(fnum(100.0 * em, 1));
        }
        t.row(row);
    }
    println!("\n## Table 12 (early-exit / DoLa-style evaluation on '{config}')\n");
    t.print();
    ctx.save_table(&format!("tab12-dola-{config}"), &t)?;
    Ok(())
}
