//! Experiment harness: one registered driver per paper table/figure
//! (DESIGN.md §5 is the index). `lisa exp <id>` runs one; `lisa exp all`
//! runs the full suite in a sensible order.

pub mod ablate;
pub mod common;
pub mod cpt;
pub mod dola;
pub mod e2e;
pub mod perfmem;
pub mod quality;
pub mod report;
pub mod theory;

use anyhow::{bail, Result};

pub use common::Ctx;

/// (id, default config, description)
pub const EXPERIMENTS: &[(&str, &str, &str)] = &[
    ("tab1-memory", "tiny", "Table 1: peak-memory grid (analytical + measured calibration)"),
    ("fig3-memory", "tiny", "Fig 3: LLaMA-2-7B memory breakdown by method"),
    ("fig4-itertime", "small", "Fig 4: single-iteration time by method + 7B FLOP projection"),
    ("fig1-loss", "small", "Fig 1: train-loss curves FT/LoRA/GaLore/LISA (+Fig 11 val loss)"),
    ("fig2-weightnorm", "small", "Fig 2/12: layerwise weight-norm skew LoRA vs FT"),
    ("suite-finetune", "small", "Tables 2, 3, 8 + memorization probe in one pass"),
    ("tab2-benchmarks", "small", "Table 2 (alias of suite-finetune)"),
    ("tab3-mtbench", "small", "Table 3 (alias of suite-finetune)"),
    ("tab8-mtbench-cat", "small", "Table 8 (alias of suite-finetune)"),
    ("tab4-cpt", "small", "Table 4: continual pre-training → GSM8K-proxy"),
    ("fig7-cpt-gamma", "small", "Fig 7: CPT γ sweep"),
    ("tab5-large", "base", "Table 5/9: largest-config stand-in (MT-Bench/GSM8K/PubMedQA proxies)"),
    ("tab6-hparams", "small", "Table 6 + Figs 8/9: γ × K ablation"),
    ("tab7-seeds", "small", "Table 7 + Fig 10: seed sensitivity"),
    ("tab10-gamma-lr", "tiny", "Table 10: γ × learning-rate grid (GSM8K-proxy)"),
    ("tab11-fixed", "small", "Table 11: LISA vs fixed layer subsets"),
    ("tab12-dola", "small", "Table 12: early-exit (DoLa) evaluation"),
    ("lisa-weighted", "small", "Extension: weighted importance sampling (Limitations §)"),
    ("lisa-grad", "small", "Extension: gradient-adaptive importance sampling (GRASS direction)"),
    ("theory-convergence", "tiny", "Theorem 1: O(1/sqrt(T)) average-regret check on convex quadratics"),
    ("e2e", "base", "End-to-end system driver (train + eval + checkpoint + profile)"),
];

pub fn list() {
    println!("{:<18} {:<7} description", "id", "config");
    for (id, cfg, desc) in EXPERIMENTS {
        println!("{id:<18} {cfg:<7} {desc}");
    }
    println!("\nregistered strategies (train --method / experiment arms):");
    for r in crate::strategy::registry() {
        println!("{:<12} lr {:<8} {}", r.name, format!("{:.0e}", r.default_lr), r.summary);
    }
}

pub fn run(ctx: &Ctx, id: &str, config_override: Option<&str>, steps: Option<usize>) -> Result<()> {
    let default_cfg = EXPERIMENTS
        .iter()
        .find(|(eid, _, _)| *eid == id)
        .map(|(_, c, _)| *c);
    let config = config_override
        .or(default_cfg)
        .unwrap_or("small")
        .to_string();
    let c = &config;
    match id {
        "tab1-memory" => perfmem::tab1_memory(ctx, c),
        "fig3-memory" => perfmem::fig3_memory(ctx, c),
        "fig4-itertime" => perfmem::fig4_itertime(ctx, c),
        "fig1-loss" | "fig11-valloss" | "fig6-convergence" => quality::fig1_loss(ctx, c),
        "fig2-weightnorm" => quality::fig2_weightnorm(ctx, c),
        "suite-finetune" | "tab2-benchmarks" | "tab3-mtbench" | "tab8-mtbench-cat" => {
            quality::suite_finetune(ctx, c)
        }
        "tab4-cpt" => cpt::tab4_cpt(ctx, c),
        "fig7-cpt-gamma" => cpt::fig7_cpt_gamma(ctx, c),
        "tab5-large" | "tab9-70b-cat" => quality::tab5_large(ctx, c),
        "tab6-hparams" | "fig8-gamma-loss" | "fig9-periodK" => ablate::tab6_hparams(ctx, c),
        "tab7-seeds" | "fig10-randomness" => ablate::tab7_seeds(ctx, c),
        "tab10-gamma-lr" => ablate::tab10_gamma_lr(ctx, c),
        "tab11-fixed" => ablate::tab11_fixed(ctx, c),
        "tab12-dola" => dola::tab12_dola(ctx, c),
        "lisa-weighted" => ablate::lisa_weighted(ctx, c),
        "lisa-grad" => ablate::lisa_grad(ctx, c),
        "theory-convergence" => theory::theory_convergence(ctx, c),
        "report" => report::write_report(ctx),
        "e2e" => e2e::e2e(ctx, c, steps),
        "all" => {
            // every distinct driver once, cheapest configs first
            for id in [
                "tab1-memory", "fig3-memory", "fig4-itertime", "fig2-weightnorm",
                "suite-finetune", "fig1-loss", "tab4-cpt", "fig7-cpt-gamma",
                "tab6-hparams", "tab7-seeds", "tab10-gamma-lr", "tab11-fixed",
                "tab12-dola", "lisa-weighted", "lisa-grad", "theory-convergence",
            ] {
                println!("\n==================== exp {id} ====================");
                run(ctx, id, config_override, steps)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment '{other}' (try `lisa exp list`)"),
    }
}
