//! Memory & iteration-time experiments:
//!
//! * `tab1-memory` — the Table-1 grid: analytical paper-scale rows plus
//!   *measured* rows for our trainable configs (meter-calibrated).
//! * `fig3-memory` — LLaMA-2-7B breakdown by category (analytical) plus
//!   the measured breakdown of the local config.
//! * `fig4-itertime` — measured per-step wall-clock per method plus the
//!   FLOP-model projection to 7B.

use anyhow::Result;

use crate::membench::{self, MemMethod, PAPER_MODELS};
use crate::opt::StatePolicy;
use crate::strategy::StrategySpec;
use crate::train::TrainConfig;
use crate::util::table::{fnum, human_bytes, Table};

use super::common::{run_arm, sft_task, Ctx};

/// Measure peak bytes of a few steps of each method on a local config.
fn measured_rows(ctx: &Ctx, config: &str) -> Result<Table> {
    let rt = ctx.runtime(config)?;
    let mut task = sft_task(&rt, 128, 0.1, ctx.seed);
    let mut t = Table::new(vec![
        "method", "measured peak", "params", "grads", "optim", "acts", "lora", "device",
    ]);
    let n_layers = rt.manifest.n_layers;
    let specs: Vec<(String, StrategySpec)> = vec![
        ("vanilla(FT)".into(), StrategySpec::ft()),
        ("lora".into(), StrategySpec::lora()),
        ("lisa E+H+2L (drop)".into(), StrategySpec::lisa(2.min(n_layers), 5)),
    ];
    for (label, spec) in specs {
        let cfg = TrainConfig {
            steps: 6,
            lr: spec.default_lr(),
            seed: ctx.seed,
            state_policy: StatePolicy::Drop,
            log_every: 0,
            ..Default::default()
        };
        let (res, _sess) = run_arm(&rt, &spec, cfg, &mut task.train)?;
        let get = |k: &str| {
            res.mem_breakdown
                .iter()
                .find(|(n, _)| *n == k)
                .map(|(_, b)| human_bytes(*b))
                .unwrap_or_else(|| "0".into())
        };
        t.row(vec![
            label,
            human_bytes(res.peak_mem),
            get("params"),
            get("grads"),
            get("optim"),
            get("activations"),
            get("lora"),
            get("device"),
        ]);
    }
    Ok(t)
}

pub fn tab1_memory(ctx: &Ctx, config: &str) -> Result<()> {
    println!("\n## Table 1 (peak memory, analytical model at paper scale: fp16 w/g/m/v, T=1024, B=1)\n");
    let t = membench::table1();
    t.print();
    ctx.save_table("tab1-memory", &t)?;

    println!("\n## Table 1 calibration: measured bytes on local config '{config}' (f32 runtime)\n");
    let m = measured_rows(ctx, config)?;
    m.print();
    ctx.save_table(&format!("tab1-measured-{config}"), &m)?;
    Ok(())
}

pub fn fig3_memory(ctx: &Ctx, _config: &str) -> Result<()> {
    println!("\n## Fig 3 (LLaMA-2-7B memory breakdown by method, analytical)\n");
    let t = membench::fig3_breakdown();
    t.print();
    ctx.save_table("fig3-memory", &t)?;
    Ok(())
}

pub fn fig4_itertime(ctx: &Ctx, config: &str) -> Result<()> {
    let rt = ctx.runtime(config)?;
    let mut task = sft_task(&rt, 128, 0.1, ctx.seed);
    let steps = ctx.steps(10).max(4);

    let mut t = Table::new(vec![
        "method", "median ms/step", "speedup vs FT", "bwd_full", "bwd_x", "bwd_skipped",
    ]);
    let mut ft_ms = 0.0f64;
    let specs: Vec<StrategySpec> = vec![
        StrategySpec::ft(),
        StrategySpec::lora(),
        StrategySpec::galore(8).with("update-proj-gap", 50usize).with("scale", 1.0f32),
        StrategySpec::lisa(2, 5),
    ];
    for spec in specs {
        let cfg = TrainConfig { steps, lr: spec.default_lr(), seed: ctx.seed, log_every: 0, ..Default::default() };
        // warm the executable cache before timing
        let (res, sess) = run_arm(&rt, &spec, cfg.clone(), &mut task.train)?;
        let label = sess.label().to_string();
        let (res, _s) = if res.median_step_ms() > 0.0 {
            run_arm(&rt, &spec, cfg, &mut task.train)?
        } else {
            (res, sess)
        };
        let ms = res.median_step_ms();
        if label == "ft" {
            ft_ms = ms;
        }
        t.row(vec![
            label,
            fnum(ms, 1),
            if ft_ms > 0.0 { format!("{:.2}x", ft_ms / ms) } else { "-".into() },
            res.bwd_full_calls.to_string(),
            res.bwd_x_calls.to_string(),
            res.bwd_skipped.to_string(),
        ]);
    }
    println!("\n## Fig 4 (single-iteration time, measured on '{config}')\n");
    t.print();
    ctx.save_table(&format!("fig4-itertime-{config}"), &t)?;

    // FLOP-model projection to the paper's 7B testbed.
    let mut proj = Table::new(vec!["method", "TFLOPs/step @7B", "speedup vs FT"]);
    let m7 = PAPER_MODELS[3];
    let ft = membench::step_flops(&m7, MemMethod::Vanilla) as f64;
    for (label, mm) in [
        ("FT", MemMethod::Vanilla),
        ("LoRA r=128", MemMethod::Lora { rank: 128 }),
        ("LISA E+H+2L", MemMethod::Lisa { extra_layers: 2 }),
    ] {
        let f = membench::step_flops(&m7, mm) as f64;
        proj.row(vec![
            label.to_string(),
            fnum(f / 1e12, 1),
            format!("{:.2}x", ft / f),
        ]);
    }
    println!("\n## Fig 4b (FLOP-model projection to LLaMA-2-7B)\n");
    proj.print();
    ctx.save_table("fig4-flop-projection", &proj)?;
    Ok(())
}
