//! Shared plumbing for the experiment drivers: context, suite setup
//! (corpus → tokenizer → loaders → runtime), arm execution and CSV/markdown
//! emission.

use std::path::{Path, PathBuf};

use anyhow::{Context as _, Result};

use crate::data::{
    corpus, encode_lm_stream, encode_sft, split_train_val, DataLoader, Sample, Tokenizer,
};
use crate::runtime::Runtime;
use crate::strategy::StrategySpec;
use crate::train::{TrainConfig, TrainResult, TrainSession};
use crate::util::table::Table;

/// Experiment context from the CLI.
#[derive(Debug, Clone)]
pub struct Ctx {
    pub artifacts: PathBuf,
    pub results: PathBuf,
    pub backend: String,
    /// Step-budget multiplier (`--scale 0.25` for smoke runs).
    pub scale: f64,
    pub seed: u64,
    /// Crash-safe mode (`--save-every N`): training arms that support it
    /// checkpoint their full state every N steps and resume from an
    /// existing state file on restart. 0 = off.
    pub save_every: usize,
    /// Explicit checkpoint to resume the driver's training run from
    /// (`--resume PATH`; e2e).
    pub resume: Option<PathBuf>,
    /// Decode-time sampling policy for the generative metrics and
    /// qualitative samples (`--sample/--temperature/--top-k/--top-p`;
    /// greedy by default, which reproduces the PR 4 tables).
    pub sampler: crate::engine::SamplerSpec,
    /// Base seed of the per-request sampler streams (`--gen-seed`).
    pub gen_seed: u64,
}

impl Ctx {
    pub fn steps(&self, base: usize) -> usize {
        ((base as f64) * self.scale).round().max(2.0) as usize
    }

    pub fn runtime(&self, config: &str) -> Result<Runtime> {
        let dir = self.artifacts.join(config);
        Runtime::load(&dir, &self.backend).with_context(|| {
            format!(
                "loading artifacts for '{config}' — run `make artifacts CONFIGS={config}` first"
            )
        })
    }

    pub fn save_table(&self, id: &str, t: &Table) -> Result<()> {
        std::fs::create_dir_all(&self.results)?;
        let path = self.results.join(format!("{id}.csv"));
        std::fs::write(&path, t.csv())?;
        log::info!("wrote {}", path.display());
        Ok(())
    }

    pub fn save_curve(&self, id: &str, series: &[(String, Vec<(usize, f64)>)]) -> Result<()> {
        std::fs::create_dir_all(&self.results)?;
        let mut t = Table::new(vec!["series", "step", "value"]);
        for (name, pts) in series {
            for (step, v) in pts {
                t.row(vec![name.clone(), step.to_string(), format!("{v:.6}")]);
            }
        }
        let path = self.results.join(format!("{id}.csv"));
        std::fs::write(&path, t.csv())?;
        log::info!("wrote {}", path.display());
        Ok(())
    }
}

/// A ready-to-train SFT task: tokenizer + train/val loaders (plus the raw
/// val samples, which the generative decode metrics prompt from).
pub struct SftTask {
    pub tok: Tokenizer,
    pub train: DataLoader,
    pub val: DataLoader,
    pub val_samples: Vec<Sample>,
    pub n_train: usize,
}

/// Instruction-following task (Alpaca-GPT4 proxy) for a given runtime.
pub fn sft_task(rt: &Runtime, n_samples: usize, val_frac: f64, seed: u64) -> SftTask {
    let m = &rt.manifest;
    let samples = corpus::gen_instruction_corpus(n_samples, seed);
    let tok = Tokenizer::build(&corpus::sample_texts(&samples), m.vocab);
    let (tr, va) = split_train_val(&samples, val_frac, seed ^ 0x517);
    let enc_tr: Vec<_> = tr.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
    let enc_va: Vec<_> = va.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
    let val_samples = supervised_samples(va, &enc_va);
    let train = DataLoader::new(enc_tr, m.batch, m.seq, seed ^ 0xda7a);
    let n_train = train.len();
    SftTask {
        train,
        val: DataLoader::new(enc_va, m.batch, m.seq, seed ^ 0xe7a1),
        val_samples,
        tok,
        n_train,
    }
}

/// Keep the raw samples aligned with what the loader keeps: it drops
/// zero-supervision encodings, so the teacher-forced and generative val
/// metrics must score the same sample set (and `n_train` must report
/// what was actually trained on — take it from the built loader).
fn supervised_samples(samples: Vec<Sample>, enc: &[crate::data::Encoded]) -> Vec<Sample> {
    samples
        .into_iter()
        .zip(enc)
        .filter(|(_, e)| e.n_supervised() > 0)
        .map(|(s, _)| s)
        .collect()
}

/// Slice of val samples for the generative decode metrics, plus a
/// `max_new` budget that fits the longest reference response (+`<eos>`),
/// capped at the artifact window. Takes the fields (not the task) so
/// callers can keep a disjoint `&mut task.train` borrow alive.
pub fn gen_slice<'a>(
    val_samples: &'a [Sample],
    tok: &Tokenizer,
    cap: usize,
    seq: usize,
) -> (&'a [Sample], usize) {
    let s = &val_samples[..val_samples.len().min(cap)];
    let max_new = s
        .iter()
        .map(|x| tok.encode(&x.response).len() + 1)
        .max()
        .unwrap_or(8)
        .min(seq);
    (s, max_new)
}

/// Math-problem task (GSM8K proxy). Tokenizer is built over both the CPT
/// docs and the problems so the CPT → FT pipeline shares one vocab.
pub struct MathTask {
    pub tok: Tokenizer,
    pub cpt: DataLoader,
    pub train: DataLoader,
    pub test: DataLoader,
}

pub fn math_task(rt: &Runtime, n_problems: usize, n_docs: usize, seed: u64) -> MathTask {
    let m = &rt.manifest;
    let docs = corpus::gen_cpt_math_docs(n_docs, 6, seed ^ 0xd0c5);
    let problems = corpus::gen_math_problems(n_problems, seed, 3);
    let mut texts = docs.clone();
    texts.extend(corpus::sample_texts(&problems));
    let tok = Tokenizer::build(&texts, m.vocab);
    let (tr, te) = split_train_val(&problems, 0.25, seed ^ 0x7e57);
    let enc_cpt = encode_lm_stream(&tok, &docs, m.seq);
    let enc_tr: Vec<_> = tr.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
    let enc_te: Vec<_> = te.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
    MathTask {
        cpt: DataLoader::new(enc_cpt, m.batch, m.seq, seed ^ 1),
        train: DataLoader::new(enc_tr, m.batch, m.seq, seed ^ 2),
        test: DataLoader::new(enc_te, m.batch, m.seq, seed ^ 3),
        tok,
    }
}

/// Medical-QA task (PubMedQA proxy).
pub fn medqa_task(rt: &Runtime, n: usize, seed: u64) -> SftTask {
    let m = &rt.manifest;
    let samples = corpus::gen_medqa(n, seed);
    let tok = Tokenizer::build(&corpus::sample_texts(&samples), m.vocab);
    let (tr, va) = split_train_val(&samples, 0.2, seed ^ 0x3d);
    let enc_tr: Vec<_> = tr.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
    let enc_va: Vec<_> = va.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
    let val_samples = supervised_samples(va, &enc_va);
    let train = DataLoader::new(enc_tr, m.batch, m.seq, seed ^ 4);
    let n_train = train.len();
    SftTask {
        train,
        val: DataLoader::new(enc_va, m.batch, m.seq, seed ^ 5),
        val_samples,
        tok,
        n_train,
    }
}

/// Train one arm from its registry spec and return (result, session) — the
/// session keeps the trained parameters for evaluation.
pub fn run_arm<'rt>(
    rt: &'rt Runtime,
    spec: &StrategySpec,
    cfg: TrainConfig,
    loader: &mut DataLoader,
) -> Result<(TrainResult, TrainSession<'rt>)> {
    run_arm_ckpt(rt, spec, cfg, loader, None)
}

/// [`run_arm`] with crash-safe checkpointing: when `state` names a path
/// and a period, the arm saves its full training state there every
/// `every` steps and — if the file already exists from an interrupted
/// run — resumes from it instead of starting over (Ctx `--save-every`).
pub fn run_arm_ckpt<'rt>(
    rt: &'rt Runtime,
    spec: &StrategySpec,
    cfg: TrainConfig,
    loader: &mut DataLoader,
    state: Option<(&Path, usize)>,
) -> Result<(TrainResult, TrainSession<'rt>)> {
    let mut sess = TrainSession::new(rt, spec, cfg)?;
    let label = sess.label();
    log::info!(
        "arm [{}] steps={} lr={:.1e} seed={}",
        label,
        sess.cfg.steps,
        sess.cfg.lr,
        sess.cfg.seed
    );
    let t0 = std::time::Instant::now();
    let res = match state {
        None => sess.run(loader)?,
        Some((path, every)) => {
            let resume = path.exists().then_some(path);
            let conf = crate::train::CheckpointConf { path: path.to_path_buf(), every };
            sess.run_resumable(loader, Some(&conf), resume)?
        }
    };
    log::info!(
        "arm [{}] done in {:.1}s (median {:.0} ms/step, final loss {:.4})",
        label,
        t0.elapsed().as_secs_f64(),
        res.median_step_ms(),
        res.final_train_loss
    );
    Ok((res, sess))
}

pub fn ensure_dir(p: &Path) -> Result<()> {
    std::fs::create_dir_all(p)?;
    Ok(())
}
