//! Word-level tokenizer with digit splitting, built from the corpus by
//! frequency (our stand-in for the models' BPE vocabularies).
//!
//! Numbers are split into single digits ("1742" -> "1 7 4 2") so the
//! arithmetic corpora are learnable by a from-scratch model — answer
//! correctness then decomposes into per-digit next-token predictions.

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
pub const UNK: i32 = 4;
pub const N_SPECIALS: usize = 5;
const SPECIAL_NAMES: [&str; N_SPECIALS] = ["<pad>", "<bos>", "<eos>", "<sep>", "<unk>"];

#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: HashMap<String, i32>,
    inv: Vec<String>,
}

/// Split text into word/digit/punctuation tokens.
pub fn pretokenize(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    for word in text.split_whitespace() {
        let mut cur = String::new();
        for c in word.chars() {
            if c.is_ascii_digit() {
                if !cur.is_empty() {
                    out.push(std::mem::take(&mut cur));
                }
                out.push(c.to_string());
            } else if c.is_alphanumeric() || c == '\'' {
                cur.push(c.to_ascii_lowercase());
            } else {
                if !cur.is_empty() {
                    out.push(std::mem::take(&mut cur));
                }
                out.push(c.to_string());
            }
        }
        if !cur.is_empty() {
            out.push(cur);
        }
    }
    out
}

impl Tokenizer {
    /// Build a vocab of at most `vocab_size` entries from the given texts,
    /// keeping the most frequent words (specials + digits always included).
    pub fn build(texts: &[String], vocab_size: usize) -> Tokenizer {
        assert!(vocab_size > N_SPECIALS + 10, "vocab too small");
        let mut freq: HashMap<String, u64> = HashMap::new();
        for t in texts {
            for tok in pretokenize(t) {
                *freq.entry(tok).or_insert(0) += 1;
            }
        }
        let mut inv: Vec<String> =
            SPECIAL_NAMES.iter().map(|s| s.to_string()).collect();
        // digits guaranteed present
        for d in 0..10 {
            let s = d.to_string();
            freq.remove(&s);
            inv.push(s);
        }
        let mut by_freq: Vec<(String, u64)> = freq.into_iter().collect();
        by_freq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        for (w, _) in by_freq.into_iter().take(vocab_size - inv.len()) {
            inv.push(w);
        }
        let vocab = inv
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as i32))
            .collect();
        Tokenizer { vocab, inv }
    }

    pub fn vocab_size(&self) -> usize {
        self.inv.len()
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        pretokenize(text)
            .into_iter()
            .map(|t| self.vocab.get(&t).copied().unwrap_or(UNK))
            .collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter(|&&i| i >= N_SPECIALS as i32 || i == UNK)
            .map(|&i| {
                self.inv
                    .get(i as usize)
                    .map(|s| s.as_str())
                    .unwrap_or("<oov>")
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn token(&self, id: i32) -> Option<&str> {
        self.inv.get(id as usize).map(|s| s.as_str())
    }

    /// Fraction of tokens in `texts` that map to `<unk>` (vocab coverage
    /// diagnostic — experiments assert this stays tiny).
    pub fn unk_rate(&self, texts: &[String]) -> f64 {
        let mut total = 0u64;
        let mut unk = 0u64;
        for t in texts {
            for id in self.encode(t) {
                total += 1;
                if id == UNK {
                    unk += 1;
                }
            }
        }
        if total == 0 {
            0.0
        } else {
            unk as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Tokenizer {
        let texts = vec![
            "the cat sat on the mat .".to_string(),
            "the dog ate 42 apples !".to_string(),
        ];
        Tokenizer::build(&texts, 64)
    }

    #[test]
    fn digits_split() {
        assert_eq!(
            pretokenize("x42y 1742"),
            vec!["x", "4", "2", "y", "1", "7", "4", "2"]
        );
    }

    #[test]
    fn punctuation_separated() {
        assert_eq!(pretokenize("cat, dog."), vec!["cat", ",", "dog", "."]);
    }

    #[test]
    fn roundtrip_known_words() {
        let t = toy();
        let ids = t.encode("the cat ate 4 2");
        assert!(!ids.contains(&UNK));
        assert_eq!(t.decode(&ids), "the cat ate 4 2");
    }

    #[test]
    fn unknown_maps_to_unk() {
        let t = toy();
        let ids = t.encode("zebra");
        assert_eq!(ids, vec![UNK]);
        assert!(t.unk_rate(&vec!["zebra zebra".into()]) == 1.0);
    }

    #[test]
    fn specials_and_digits_reserved() {
        let t = toy();
        assert_eq!(t.token(PAD), Some("<pad>"));
        assert_eq!(t.token(UNK), Some("<unk>"));
        assert_eq!(t.encode("7"), vec![N_SPECIALS as i32 + 7]);
    }

    #[test]
    fn vocab_respects_size_and_freq() {
        let texts: Vec<String> = (0..100)
            .map(|i| format!("common word{} rare{}", i % 3, i))
            .collect();
        let t = Tokenizer::build(&texts, 20);
        assert!(t.vocab_size() <= 20);
        // 'common' must be in vocab, some rareN must not
        assert!(!t.encode("common").contains(&UNK));
    }

    #[test]
    fn deterministic_given_same_input() {
        let texts = vec!["a b c a b a".to_string()];
        let t1 = Tokenizer::build(&texts, 32);
        let t2 = Tokenizer::build(&texts, 32);
        assert_eq!(t1.encode("a b c"), t2.encode("a b c"));
    }
}
