//! Encoding + batching: SFT-style prompt-masked next-token targets, LM-style
//! continual-pretraining chunks, deterministic shuffled epochs.
//!
//! Target convention (matches the `head_*` artifacts): `targets[t]` is the
//! token the model must predict *after* seeing `tokens[..=t]`, with `-1` at
//! unsupervised positions (prompt tokens in SFT, padding everywhere).

use crate::engine::Batch;
use crate::runtime::HostTensorI32;
use crate::util::rng::Rng;

use super::corpus::{Category, Sample};
use super::tokenizer::{Tokenizer, BOS, EOS, PAD, SEP};

/// One encoded, seq-length-padded training example.
#[derive(Debug, Clone)]
pub struct Encoded {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub category: Option<Category>,
    /// Target-index span of the exact-match answer, if any.
    pub answer_span: Option<(usize, usize)>,
    pub fact_id: Option<usize>,
}

impl Encoded {
    pub fn n_supervised(&self) -> usize {
        self.targets.iter().filter(|&&t| t >= 0).count()
    }
}

/// SFT encoding: `<bos> prompt <sep> response <eos>`, loss only on the
/// response (+ `<eos>`).
pub fn encode_sft(tok: &Tokenizer, s: &Sample, seq_len: usize) -> Encoded {
    let mut seq = vec![BOS];
    seq.extend(tok.encode(&s.prompt));
    let sep_pos = seq.len();
    seq.push(SEP);
    seq.extend(tok.encode(&s.response));
    seq.push(EOS);
    seq.truncate(seq_len + 1);

    // answer span in seq coordinates (the answer is the response suffix
    // just before <eos>)
    let ans_seq_span = s.answer.as_ref().and_then(|a| {
        let ans_ids = tok.encode(a);
        if ans_ids.is_empty() {
            return None;
        }
        let end = seq.len().saturating_sub(1); // drop <eos> (may be truncated away)
        let has_eos = *seq.last()? == EOS;
        let end = if has_eos { end } else { seq.len() };
        if end < ans_ids.len() {
            return None;
        }
        let start = end - ans_ids.len();
        if seq[start..end] == ans_ids[..] {
            Some((start, end))
        } else {
            None
        }
    });

    let mut tokens = vec![PAD; seq_len];
    let mut targets = vec![-1; seq_len];
    let n = seq.len().min(seq_len + 1);
    for t in 0..n.saturating_sub(1) {
        tokens[t] = seq[t];
        // supervise only predictions of post-<sep> content
        if t + 1 > sep_pos {
            targets[t] = seq[t + 1];
        }
    }
    if n <= seq_len && n > 0 {
        // last real token still needs to sit in `tokens` when it has no
        // target (e.g. sequences shorter than seq_len)
        tokens[n - 1] = seq[n - 1];
    }

    let answer_span = ans_seq_span.and_then(|(s0, e0)| {
        // target index for seq position p is p-1
        if s0 == 0 {
            return None;
        }
        let (ts, te) = (s0 - 1, e0 - 1);
        if te <= seq_len {
            Some((ts, te))
        } else {
            None
        }
    });

    Encoded {
        tokens,
        targets,
        category: Some(s.category),
        answer_span,
        fact_id: s.fact_id,
    }
}

/// Plain-LM encoding for continual pretraining: documents are concatenated
/// with `<eos>` separators and chunked into full windows; every position is
/// supervised.
pub fn encode_lm_stream(tok: &Tokenizer, docs: &[String], seq_len: usize) -> Vec<Encoded> {
    let mut stream: Vec<i32> = Vec::new();
    for d in docs {
        stream.push(BOS);
        stream.extend(tok.encode(d));
        stream.push(EOS);
    }
    let mut out = Vec::new();
    let window = seq_len + 1;
    let mut i = 0;
    while i + window <= stream.len() {
        let seq = &stream[i..i + window];
        out.push(Encoded {
            tokens: seq[..seq_len].to_vec(),
            targets: seq[1..].to_vec(),
            category: None,
            answer_span: None,
            fact_id: None,
        });
        i += seq_len;
    }
    out
}

/// Deterministic train/val split (no overlap, preserves order within each).
pub fn split_train_val<T: Clone>(items: &[T], val_frac: f64, seed: u64) -> (Vec<T>, Vec<T>) {
    let mut idx: Vec<usize> = (0..items.len()).collect();
    Rng::new(seed).shuffle(&mut idx);
    let n_val = ((items.len() as f64) * val_frac).round() as usize;
    let val_set: std::collections::BTreeSet<usize> = idx[..n_val].iter().copied().collect();
    let mut train = Vec::with_capacity(items.len() - n_val);
    let mut val = Vec::with_capacity(n_val);
    for (i, it) in items.iter().enumerate() {
        if val_set.contains(&i) {
            val.push(it.clone());
        } else {
            train.push(it.clone());
        }
    }
    (train, val)
}

/// Cycling, reshuffling batch iterator.
pub struct DataLoader {
    data: Vec<Encoded>,
    batch: usize,
    seq: usize,
    rng: Rng,
    order: Vec<usize>,
    cursor: usize,
    pub epochs: usize,
}

impl DataLoader {
    pub fn new(data: Vec<Encoded>, batch: usize, seq: usize, seed: u64) -> Self {
        Self::try_new(data, batch, seq, seed).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Build a loader, dropping examples with zero supervised positions
    /// (e.g. an SFT sample whose prompt fills the whole window after
    /// truncation). Such examples contribute nothing to the masked loss,
    /// and a batch made entirely of them turns the masked-mean loss
    /// degenerate (NaN under an unclamped denominator) — which then
    /// poisons the optimizer moments for good. Drops are logged; a
    /// dataset with nothing left is an error.
    pub fn try_new(
        data: Vec<Encoded>,
        batch: usize,
        seq: usize,
        seed: u64,
    ) -> anyhow::Result<Self> {
        use anyhow::ensure;
        ensure!(!data.is_empty(), "empty dataset");
        let n_before = data.len();
        let data: Vec<Encoded> = data.into_iter().filter(|e| e.n_supervised() > 0).collect();
        let dropped = n_before - data.len();
        if dropped > 0 {
            log::warn!(
                "dataloader: dropped {dropped}/{n_before} examples with zero supervised \
                 tokens (prompt fills the whole {seq}-token window?)"
            );
        }
        ensure!(
            !data.is_empty(),
            "all {n_before} examples have zero supervised tokens — nothing to learn \
             from (prompts fill the whole {seq}-token window?)"
        );
        for e in &data {
            ensure!(e.tokens.len() == seq, "encoded seq length mismatch");
        }
        let mut dl = DataLoader {
            order: (0..data.len()).collect(),
            data,
            batch,
            seq,
            rng: Rng::new(seed),
            cursor: 0,
            epochs: 0,
        };
        dl.rng.shuffle(&mut dl.order);
        Ok(dl)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn steps_per_epoch(&self) -> usize {
        (self.data.len() / self.batch).max(1)
    }

    /// Next `[B, T]` batch, cycling (and reshuffling) at epoch end.
    pub fn next_batch(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.cursor = 0;
                self.epochs += 1;
                self.rng.shuffle(&mut self.order);
            }
            let e = &self.data[self.order[self.cursor]];
            self.cursor += 1;
            tokens.extend_from_slice(&e.tokens);
            targets.extend_from_slice(&e.targets);
        }
        Batch {
            tokens: HostTensorI32::from_vec(&[self.batch, self.seq], tokens),
            targets: HostTensorI32::from_vec(&[self.batch, self.seq], targets),
        }
    }

    /// Fixed-order batches over the whole set (evaluation); the tail that
    /// doesn't fill a batch is padded with repeats of the last example and
    /// the returned `n_real` says how many rows are genuine.
    pub fn eval_batches(&self) -> Vec<(Batch, usize)> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.data.len() {
            let mut tokens = Vec::with_capacity(self.batch * self.seq);
            let mut targets = Vec::with_capacity(self.batch * self.seq);
            let mut n_real = 0;
            for b in 0..self.batch {
                let idx = (i + b).min(self.data.len() - 1);
                if i + b < self.data.len() {
                    n_real += 1;
                }
                let e = &self.data[idx];
                tokens.extend_from_slice(&e.tokens);
                // padded duplicate rows are unsupervised so they don't
                // perturb the loss average
                if i + b < self.data.len() {
                    targets.extend_from_slice(&e.targets);
                } else {
                    targets.extend(std::iter::repeat(-1).take(self.seq));
                }
            }
            out.push((
                Batch {
                    tokens: HostTensorI32::from_vec(&[self.batch, self.seq], tokens),
                    targets: HostTensorI32::from_vec(&[self.batch, self.seq], targets),
                },
                n_real,
            ));
            i += self.batch;
        }
        out
    }

    pub fn examples(&self) -> &[Encoded] {
        &self.data
    }

    /// Serialize the iteration state — shuffle RNG, epoch permutation,
    /// cursor, epoch count — so a resumed run sees the exact batch
    /// sequence the uninterrupted run would have (resume protocol,
    /// DESIGN.md §7). The encoded examples themselves are *not* persisted;
    /// they regenerate deterministically from the corpus seed.
    pub fn save_state(&self, sec: &mut crate::model::checkpoint::Section<'_>) {
        sec.put_rng("loader.rng", &self.rng);
        sec.put_u64s(
            "loader.order",
            self.order.iter().map(|&i| i as u64).collect(),
        );
        sec.put_u64("loader.cursor", self.cursor as u64);
        sec.put_u64("loader.epochs", self.epochs as u64);
    }

    /// Restore the state written by [`DataLoader::save_state`]. The loader
    /// must have been rebuilt over the same dataset (the order must be a
    /// permutation of its indices).
    pub fn load_state(
        &mut self,
        sec: &mut crate::model::checkpoint::Section<'_>,
    ) -> anyhow::Result<()> {
        use anyhow::ensure;
        self.rng = sec.take_rng("loader.rng")?;
        let order = sec.take_u64s("loader.order")?;
        ensure!(
            order.len() == self.data.len(),
            "loader order length {} != dataset size {} — resumed with a \
             different corpus, or a checkpoint written before the loader \
             filtered zero-supervision examples out of this dataset?",
            order.len(),
            self.data.len()
        );
        let mut seen = vec![false; self.data.len()];
        for &i in &order {
            let i = i as usize;
            ensure!(
                i < seen.len() && !std::mem::replace(&mut seen[i], true),
                "loader order is not a permutation (corrupt checkpoint)"
            );
        }
        self.order = order.into_iter().map(|i| i as usize).collect();
        let cursor = sec.take_u64("loader.cursor")? as usize;
        ensure!(
            cursor <= self.order.len(),
            "loader cursor {cursor} out of range"
        );
        self.cursor = cursor;
        self.epochs = sec.take_u64("loader.epochs")? as usize;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::gen_instruction_corpus;
    use crate::data::tokenizer::Tokenizer;

    fn setup() -> (Tokenizer, Vec<Sample>) {
        let samples = gen_instruction_corpus(64, 1);
        let texts = crate::data::corpus::sample_texts(&samples);
        (Tokenizer::build(&texts, 512), samples)
    }

    #[test]
    fn sft_masks_prompt_supervises_response() {
        let (tok, samples) = setup();
        let e = encode_sft(&tok, &samples[0], 64);
        assert_eq!(e.tokens.len(), 64);
        // some -1 (prompt) and some supervised positions
        assert!(e.n_supervised() > 0);
        assert!(e.targets.iter().any(|&t| t == -1));
        // first token is BOS
        assert_eq!(e.tokens[0], BOS);
        // supervised targets must be valid token ids
        for &t in e.targets.iter().filter(|&&t| t >= 0) {
            assert!((t as usize) < tok.vocab_size());
        }
    }

    #[test]
    fn answer_span_matches_targets() {
        let (tok, samples) = setup();
        for s in samples.iter().filter(|s| s.answer.is_some()) {
            let e = encode_sft(&tok, s, 64);
            let Some((a, b)) = e.answer_span else { continue };
            assert!(a < b && b <= 64);
            let ans_ids = tok.encode(s.answer.as_ref().unwrap());
            let span: Vec<i32> = e.targets[a..b].to_vec();
            assert_eq!(span, ans_ids, "span must be the answer tokens");
        }
    }

    #[test]
    fn lm_stream_full_supervision() {
        let (tok, _) = setup();
        let docs = vec!["compute : 1 plus 2 = 3 .".to_string(); 20];
        let enc = encode_lm_stream(&tok, &docs, 16);
        assert!(!enc.is_empty());
        for e in &enc {
            assert_eq!(e.n_supervised(), 16);
            // targets are tokens shifted by one
            assert_eq!(e.tokens[1..], e.targets[..15]);
        }
    }

    #[test]
    fn split_is_disjoint_and_total() {
        let items: Vec<usize> = (0..100).collect();
        let (tr, va) = split_train_val(&items, 0.1, 7);
        assert_eq!(tr.len(), 90);
        assert_eq!(va.len(), 10);
        let mut all: Vec<usize> = tr.iter().chain(va.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, items);
    }

    #[test]
    fn loader_cycles_and_reshuffles() {
        let (tok, samples) = setup();
        let enc: Vec<Encoded> = samples.iter().map(|s| encode_sft(&tok, s, 32)).collect();
        let mut dl = DataLoader::new(enc, 4, 32, 3);
        let spe = dl.steps_per_epoch();
        for _ in 0..spe {
            let b = dl.next_batch();
            assert_eq!(b.tokens.shape, vec![4, 32]);
        }
        assert_eq!(dl.epochs, 0);
        dl.next_batch();
        assert_eq!(dl.epochs, 1);
    }

    #[test]
    fn loader_state_roundtrip_reproduces_batch_sequence() {
        let (tok, samples) = setup();
        let enc: Vec<Encoded> = samples.iter().map(|s| encode_sft(&tok, s, 32)).collect();
        let mut full = DataLoader::new(enc.clone(), 4, 32, 9);
        let mut part1 = DataLoader::new(enc.clone(), 4, 32, 9);
        // advance past an epoch boundary so rng/order/epochs all matter
        let k = full.steps_per_epoch() + 3;
        for _ in 0..k {
            let a = full.next_batch();
            let b = part1.next_batch();
            assert_eq!(a.tokens.data, b.tokens.data);
        }
        let mut sec = crate::model::checkpoint::Section::new("loader");
        part1.save_state(&mut sec);
        // resume into a loader built with a different seed: restored state wins
        let mut part2 = DataLoader::new(enc, 4, 32, 12345);
        part2.load_state(&mut sec).unwrap();
        assert!(sec.is_empty());
        assert_eq!(part2.epochs, full.epochs);
        for step in 0..3 * full.steps_per_epoch() {
            let a = full.next_batch();
            let b = part2.next_batch();
            assert_eq!(a.tokens.data, b.tokens.data, "tokens diverged at step {step}");
            assert_eq!(a.targets.data, b.targets.data, "targets diverged at step {step}");
        }
        assert_eq!(part2.epochs, full.epochs);
    }

    #[test]
    fn loader_state_rejects_size_mismatch() {
        let (tok, samples) = setup();
        let enc: Vec<Encoded> = samples.iter().map(|s| encode_sft(&tok, s, 32)).collect();
        let dl = DataLoader::new(enc.clone(), 4, 32, 9);
        let mut sec = crate::model::checkpoint::Section::new("loader");
        dl.save_state(&mut sec);
        let mut smaller = DataLoader::new(enc[..enc.len() - 2].to_vec(), 4, 32, 9);
        assert!(smaller.load_state(&mut sec).is_err());
    }

    #[test]
    fn loader_drops_zero_supervision_examples_with_survivors() {
        let (tok, samples) = setup();
        let mut enc: Vec<Encoded> =
            samples.iter().take(6).map(|s| encode_sft(&tok, s, 32)).collect();
        let mut dead = enc[0].clone();
        dead.targets = vec![-1; 32];
        enc.push(dead);
        let dl = DataLoader::new(enc, 2, 32, 1);
        assert_eq!(dl.len(), 6, "the all-masked example must be filtered out");
        assert!(dl.examples().iter().all(|e| e.n_supervised() > 0));
    }

    #[test]
    fn loader_errors_when_nothing_supervised_survives() {
        let (tok, samples) = setup();
        let mut e = encode_sft(&tok, &samples[0], 32);
        e.targets = vec![-1; 32];
        let err = DataLoader::try_new(vec![e], 2, 32, 1).unwrap_err();
        assert!(err.to_string().contains("zero supervised"), "got: {err}");
    }

    #[test]
    fn window_filling_prompt_encodes_unsupervised_and_is_filtered() {
        // the real-world shape of the bug: an SFT prompt that fills the
        // whole window after truncation leaves no supervised position
        let (tok, samples) = setup();
        let long = crate::data::Sample {
            prompt: "what is 1 plus 2 ".repeat(16),
            response: "answer : 3".to_string(),
            category: samples[0].category,
            answer: None,
            fact_id: None,
        };
        let e = encode_sft(&tok, &long, 16);
        assert_eq!(e.n_supervised(), 0);
        let good = encode_sft(&tok, &samples[0], 16);
        let dl = DataLoader::new(vec![e, good], 1, 16, 1);
        assert_eq!(dl.len(), 1);
    }

    #[test]
    fn eval_batches_cover_everything_once() {
        let (tok, samples) = setup();
        let enc: Vec<Encoded> = samples.iter().map(|s| encode_sft(&tok, s, 32)).collect();
        let n = enc.len();
        let dl = DataLoader::new(enc, 6, 32, 3);
        let batches = dl.eval_batches();
        let total_real: usize = batches.iter().map(|(_, r)| r).sum();
        assert_eq!(total_real, n);
    }
}
