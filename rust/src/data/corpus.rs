//! Synthetic corpora — the data substrates standing in for the paper's
//! datasets (DESIGN.md §4 documents each substitution):
//!
//! * [`gen_instruction_corpus`] — Alpaca-GPT4 stand-in: templated
//!   instruction/response pairs across the eight MT-Bench categories, with
//!   a Zipf-weighted long-tail *fact table* embedded in writing/humanities
//!   samples so the paper's "LISA memorizes long-tail patterns better"
//!   claim has a measurable analog.
//! * [`gen_math_problems`] — GSM8K stand-in: 1–3-step word problems with a
//!   digit-level final answer for exact-match scoring.
//! * [`gen_cpt_math_docs`] — OpenWebMath stand-in: plain arithmetic
//!   documents for continual pre-training.
//! * [`gen_medqa`] — PubMedQA stand-in: question/context/yes-no-maybe
//!   grammar where the context entails the label.
//!
//! Everything is seeded and deterministic.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Category {
    Writing,
    Roleplay,
    Reasoning,
    Code,
    Math,
    Extraction,
    Stem,
    Humanities,
}

pub const CATEGORIES: [Category; 8] = [
    Category::Writing,
    Category::Roleplay,
    Category::Reasoning,
    Category::Code,
    Category::Math,
    Category::Extraction,
    Category::Stem,
    Category::Humanities,
];

impl Category {
    pub fn label(&self) -> &'static str {
        match self {
            Category::Writing => "writing",
            Category::Roleplay => "roleplay",
            Category::Reasoning => "reasoning",
            Category::Code => "code",
            Category::Math => "math",
            Category::Extraction => "extraction",
            Category::Stem => "stem",
            Category::Humanities => "humanities",
        }
    }
}

/// One supervised sample. `answer` (when present) is the exact-match span
/// that follows "answer :" in the response.
#[derive(Debug, Clone)]
pub struct Sample {
    pub prompt: String,
    pub response: String,
    pub category: Category,
    pub answer: Option<String>,
    /// Index into the fact table when this sample exercises a long-tail
    /// fact (the memorization probe id).
    pub fact_id: Option<usize>,
}

// ---------------------------------------------------------------------------
// Word pools
// ---------------------------------------------------------------------------

const ADJS: &[&str] = &[
    "crystal", "silver", "ancient", "golden", "marble", "hidden", "sunken",
    "burning", "frozen", "emerald", "obsidian", "ivory", "crimson", "azure",
    "gilded", "broken",
];
const NOUNS: &[&str] = &[
    "tower", "bridge", "library", "garden", "temple", "harbor", "citadel",
    "archive", "fountain", "gallery", "observatory", "amphitheater",
];
const PLACES: &[&str] = &[
    "eldoria", "varneth", "quillmar", "ostrava", "brinmoor", "calvessa",
    "drenholt", "ferrowick", "galdemar", "hollowreach", "iskarend", "jorvale",
];
const QUALITIES: &[&str] = &[
    "arches", "mosaics", "stairways", "gardens", "bells", "murals",
    "columns", "lanterns",
];
const ROLES: &[&str] = &[
    "librarian", "navigator", "blacksmith", "astronomer", "healer",
    "cartographer", "historian", "gardener",
];
const PEOPLE: &[&str] = &[
    "traveler", "student", "merchant", "scholar", "stranger", "apprentice",
];
const ITEMS: &[&str] = &[
    "apples", "coins", "books", "marbles", "stamps", "shells", "pencils",
    "tickets",
];
const ANIMALS: &[&str] = &["sparrow", "otter", "lynx", "heron", "badger", "falcon"];
const GROUPS: &[&str] = &["bird", "mammal", "hunter", "swimmer", "climber"];
const DRUGS: &[&str] = &[
    "relafen", "cortexa", "mivolin", "zanopril", "ferrodine", "luxotan",
    "novaquin", "teralith",
];
const CONDITIONS: &[&str] = &[
    "hypertension", "insomnia", "migraine", "arthritis", "anemia",
    "bronchitis", "dermatitis", "fatigue",
];
const STEM_QA: &[(&str, &str)] = &[
    ("what force pulls objects toward earth", "gravity"),
    ("what gas do plants absorb from the air", "carbon dioxide"),
    ("what particle carries negative charge", "the electron"),
    ("what organ pumps blood through the body", "the heart"),
    ("what planet is known as the red planet", "mars"),
    ("what is the boiling point of water in celsius", "1 0 0 degrees"),
    ("what metal is liquid at room temperature", "mercury"),
    ("what process turns sunlight into plant energy", "photosynthesis"),
];

/// Deterministic pseudo-name generator (builder names in the fact table).
fn gen_name(rng: &mut Rng) -> String {
    const CONS: &[&str] = &["m", "v", "r", "t", "k", "s", "d", "l", "n", "b"];
    const VOW: &[&str] = &["a", "e", "i", "o", "u"];
    let syl = |rng: &mut Rng| {
        format!("{}{}", CONS[rng.below(CONS.len())], VOW[rng.below(VOW.len())])
    };
    let first = format!("{}{}", syl(rng), syl(rng));
    let last = format!("{}{}{}", syl(rng), syl(rng), CONS[rng.below(CONS.len())]);
    format!("{first} {last}")
}

// ---------------------------------------------------------------------------
// Fact table — the long-tail memorization substrate
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fact {
    pub entity: String,  // "the crystal tower of eldoria"
    pub builder: String, // "mara venn"
    pub year: u32,       // 1000..1999
    pub quality: String,
}

#[derive(Debug, Clone)]
pub struct FactTable {
    pub facts: Vec<Fact>,
}

pub const N_FACTS: usize = 64;
const FACT_SEED: u64 = 0xFAC7;

impl FactTable {
    /// The canonical table shared by the generator and the eval probes.
    pub fn canonical() -> FactTable {
        let mut rng = Rng::new(FACT_SEED);
        let mut facts = Vec::with_capacity(N_FACTS);
        let mut seen = std::collections::BTreeSet::new();
        while facts.len() < N_FACTS {
            let entity = format!(
                "the {} {} of {}",
                ADJS[rng.below(ADJS.len())],
                NOUNS[rng.below(NOUNS.len())],
                PLACES[rng.below(PLACES.len())]
            );
            if !seen.insert(entity.clone()) {
                continue; // entities must be unique for unambiguous recall
            }
            facts.push(Fact {
                entity,
                builder: gen_name(&mut rng),
                year: 1000 + rng.below(1000) as u32,
                quality: QUALITIES[rng.below(QUALITIES.len())].to_string(),
            });
        }
        FactTable { facts }
    }

    /// Zipf-weighted fact index: head facts are common, the tail is rare —
    /// the paper's "long-tailed patterns".
    pub fn sample_zipf(&self, rng: &mut Rng) -> usize {
        let w: Vec<f64> = (0..self.facts.len()).map(|i| 1.0 / (i as f64 + 1.0)).collect();
        rng.sample_weighted(&w)
    }
}

fn spell_digits(n: u32) -> String {
    n.to_string()
        .chars()
        .map(|c| c.to_string())
        .collect::<Vec<_>>()
        .join(" ")
}

// ---------------------------------------------------------------------------
// Instruction corpus (Alpaca-GPT4 proxy)
// ---------------------------------------------------------------------------

fn gen_one(cat: Category, facts: &FactTable, rng: &mut Rng) -> Sample {
    match cat {
        Category::Writing => {
            let fi = facts.sample_zipf(rng);
            let f = &facts.facts[fi];
            let prompt = format!("write a short story about {} .", f.entity);
            let response = format!(
                "{} was built by {} in {} . it is famous for its {} . \
                 visitors come from {} to see it at dawn .",
                f.entity,
                f.builder,
                spell_digits(f.year),
                f.quality,
                PLACES[rng.below(PLACES.len())]
            );
            Sample { prompt, response, category: cat, answer: None, fact_id: Some(fi) }
        }
        Category::Roleplay => {
            let role = ROLES[rng.below(ROLES.len())];
            let person = PEOPLE[rng.below(PEOPLE.len())];
            let prompt = format!("you are a {role} . greet a {person} .");
            let response = format!(
                "welcome , {person} . i am the {role} of this place . \
                 ask me anything about my craft ."
            );
            Sample { prompt, response, category: cat, answer: None, fact_id: None }
        }
        Category::Reasoning => {
            let a = GROUPS[rng.below(GROUPS.len())];
            let mut b = GROUPS[rng.below(GROUPS.len())];
            while b == a {
                b = GROUPS[rng.below(GROUPS.len())];
            }
            let x = ANIMALS[rng.below(ANIMALS.len())];
            let prompt = format!(
                "every {a} is a {b} . the {x} is a {a} . what is the {x} ? "
            );
            let response = format!("answer : the {x} is a {b}");
            Sample {
                prompt,
                response,
                category: cat,
                answer: Some(format!("the {x} is a {b}")),
                fact_id: None,
            }
        }
        Category::Code => {
            let ops = [("add", "+"), ("sub", "-"), ("mul", "*")];
            let (name, op) = ops[rng.below(ops.len())];
            let prompt = format!("write a function named {name} of two numbers .");
            let response = format!(
                "answer : def {name} ( x , y ) : return x {op} y"
            );
            Sample {
                prompt,
                response,
                category: cat,
                answer: Some(format!("def {name} ( x , y ) : return x {op} y")),
                fact_id: None,
            }
        }
        Category::Math => {
            let a = rng.below(90) as i64 + 10;
            let b = rng.below(90) as i64 + 10;
            let (op, res) = match rng.below(3) {
                0 => ("plus", a + b),
                1 => ("minus", a - b),
                _ => ("times", a * b),
            };
            let prompt = format!("what is {a} {op} {b} ?");
            let ans = if res < 0 {
                format!("minus {}", spell_digits((-res) as u32))
            } else {
                spell_digits(res as u32)
            };
            let response = format!("answer : {ans}");
            Sample { prompt, response, category: cat, answer: Some(ans), fact_id: None }
        }
        Category::Extraction => {
            let year = 1000 + rng.below(1000) as u32;
            let name = gen_name(rng);
            let place = PLACES[rng.below(PLACES.len())];
            let prompt = format!(
                "extract the year from : the treaty of {place} was signed in {} by {name} .",
                spell_digits(year)
            );
            let ans = spell_digits(year);
            let response = format!("answer : {ans}");
            Sample { prompt, response, category: cat, answer: Some(ans), fact_id: None }
        }
        Category::Stem => {
            let (q, a) = STEM_QA[rng.below(STEM_QA.len())];
            let prompt = format!("{q} ?");
            let response = format!("answer : {a}");
            Sample {
                prompt,
                response,
                category: cat,
                answer: Some(a.to_string()),
                fact_id: None,
            }
        }
        Category::Humanities => {
            let fi = facts.sample_zipf(rng);
            let f = &facts.facts[fi];
            let (prompt, ans) = match rng.below(2) {
                0 => (format!("who built {} ?", f.entity), f.builder.clone()),
                _ => (
                    format!("in what year was {} built ?", f.entity),
                    spell_digits(f.year),
                ),
            };
            let response = format!("answer : {ans}");
            Sample {
                prompt,
                response,
                category: cat,
                answer: Some(ans),
                fact_id: Some(fi),
            }
        }
    }
}

/// `n` samples, category-balanced, Zipf-weighted fact usage.
pub fn gen_instruction_corpus(n: usize, seed: u64) -> Vec<Sample> {
    let facts = FactTable::canonical();
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| gen_one(CATEGORIES[i % CATEGORIES.len()], &facts, &mut rng))
        .collect()
}

// ---------------------------------------------------------------------------
// Math corpora (OpenWebMath / GSM8K proxies)
// ---------------------------------------------------------------------------

/// Multi-step word problems with digit-level answers (GSM8K proxy).
pub fn gen_math_problems(n: usize, seed: u64, max_steps: usize) -> Vec<Sample> {
    let mut rng = Rng::new(seed);
    let names = ["tom", "ana", "ben", "lea", "sam", "mia"];
    (0..n)
        .map(|_| {
            let who = names[rng.below(names.len())];
            let item = ITEMS[rng.below(ITEMS.len())];
            let steps = 1 + rng.below(max_steps.max(1));
            let mut total = 10 + rng.below(40) as i64;
            let mut prompt = format!("{who} has {total} {item} .");
            for _ in 0..steps {
                if rng.below(2) == 0 {
                    let d = 1 + rng.below(30) as i64;
                    total += d;
                    prompt.push_str(&format!(" {who} buys {d} more ."));
                } else {
                    let d = 1 + rng.below((total - 1).max(1) as usize) as i64;
                    total -= d;
                    prompt.push_str(&format!(" {who} gives away {d} ."));
                }
            }
            prompt.push_str(&format!(" how many {item} does {who} have ?"));
            let ans = spell_digits(total as u32);
            Sample {
                prompt,
                response: format!("answer : {ans}"),
                category: Category::Math,
                answer: Some(ans),
                fact_id: None,
            }
        })
        .collect()
}

/// Plain arithmetic documents for continual pre-training (OpenWebMath
/// proxy): lines of "compute : a op b = result".
pub fn gen_cpt_math_docs(n_docs: usize, lines_per_doc: usize, seed: u64) -> Vec<String> {
    let mut rng = Rng::new(seed);
    (0..n_docs)
        .map(|_| {
            let mut doc = String::new();
            for _ in 0..lines_per_doc {
                let a = rng.below(99) as i64 + 1;
                let b = rng.below(99) as i64 + 1;
                let (sym, res) = match rng.below(3) {
                    0 => ("plus", a + b),
                    1 => ("minus", a - b),
                    _ => ("times", a * b),
                };
                let r = if res < 0 {
                    format!("minus {}", spell_digits((-res) as u32))
                } else {
                    spell_digits(res as u32)
                };
                doc.push_str(&format!("compute : {a} {sym} {b} = {r} . "));
            }
            doc
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Medical QA (PubMedQA proxy)
// ---------------------------------------------------------------------------

pub fn gen_medqa(n: usize, seed: u64) -> Vec<Sample> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let drug = DRUGS[rng.below(DRUGS.len())];
            let cond = CONDITIONS[rng.below(CONDITIONS.len())];
            let n_pat = 20 + rng.below(400);
            let (effect, label) = match rng.below(3) {
                0 => ("significantly reduced", "yes"),
                1 => ("did not change", "no"),
                _ => ("showed mixed results for", "maybe"),
            };
            let prompt = format!(
                "question : does {drug} improve {cond} ? context : in a study \
                 of {} patients , {drug} {effect} {cond} .",
                spell_digits(n_pat as u32)
            );
            Sample {
                prompt,
                response: format!("answer : {label}"),
                category: Category::Stem,
                answer: Some(label.to_string()),
                fact_id: None,
            }
        })
        .collect()
}

/// All raw text of a sample set (tokenizer building).
pub fn sample_texts(samples: &[Sample]) -> Vec<String> {
    samples
        .iter()
        .map(|s| format!("{} {}", s.prompt, s.response))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic() {
        let a = gen_instruction_corpus(64, 1);
        let b = gen_instruction_corpus(64, 1);
        assert_eq!(a.len(), 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.response, y.response);
        }
        let c = gen_instruction_corpus(64, 2);
        assert!(a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt));
    }

    #[test]
    fn categories_balanced() {
        let s = gen_instruction_corpus(80, 3);
        for cat in CATEGORIES {
            let n = s.iter().filter(|x| x.category == cat).count();
            assert_eq!(n, 10, "{cat:?}");
        }
    }

    #[test]
    fn fact_table_canonical_and_unique() {
        let t1 = FactTable::canonical();
        let t2 = FactTable::canonical();
        assert_eq!(t1.facts.len(), N_FACTS);
        for (a, b) in t1.facts.iter().zip(&t2.facts) {
            assert_eq!(a.entity, b.entity);
            assert_eq!(a.year, b.year);
        }
        let mut ents: Vec<&str> = t1.facts.iter().map(|f| f.entity.as_str()).collect();
        ents.sort_unstable();
        ents.dedup();
        assert_eq!(ents.len(), N_FACTS, "entities must be unique");
    }

    #[test]
    fn zipf_skews_to_head() {
        let t = FactTable::canonical();
        let mut rng = Rng::new(9);
        let mut head = 0;
        let trials = 2000;
        for _ in 0..trials {
            if t.sample_zipf(&mut rng) < 8 {
                head += 1;
            }
        }
        // first 8 of 64 carry sum(1/i, i=1..8)/sum(1/i, i=1..64) ≈ 57%
        assert!(head > trials * 45 / 100, "head={head}");
    }

    #[test]
    fn math_answers_are_correct_format() {
        for s in gen_math_problems(50, 7, 3) {
            let ans = s.answer.unwrap();
            assert!(s.response.ends_with(&ans));
            assert!(ans.split(' ').all(|d| d.len() == 1 && d.chars().all(|c| c.is_ascii_digit())));
        }
    }

    #[test]
    fn medqa_label_consistent_with_context() {
        for s in gen_medqa(60, 5) {
            let a = s.answer.unwrap();
            if s.prompt.contains("significantly reduced") {
                assert_eq!(a, "yes");
            } else if s.prompt.contains("did not change") {
                assert_eq!(a, "no");
            } else {
                assert_eq!(a, "maybe");
            }
        }
    }

    #[test]
    fn cpt_docs_contain_correct_arithmetic() {
        let docs = gen_cpt_math_docs(5, 4, 11);
        assert_eq!(docs.len(), 5);
        for d in &docs {
            assert!(d.contains("compute :"));
        }
    }
}
