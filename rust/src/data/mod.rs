//! Data substrates: synthetic corpora, tokenizer, encoding, batching.

pub mod corpus;
pub mod loader;
pub mod tokenizer;

pub use corpus::{Category, FactTable, Sample, CATEGORIES};
pub use loader::{encode_lm_stream, encode_sft, split_train_val, DataLoader, Encoded};
pub use tokenizer::Tokenizer;
