"""Quantized-base correctness (DESIGN.md §15).

Three contracts, mirrored by `rust/src/opt/quant.rs` and `it_quant.rs`:

1. `quantize_per_channel` round-trip properties — per-channel absmax
   scaling, extreme channels survive exactly, zero channels reproduce
   exact zeros, NaN/Inf reject.
2. The fused-dequant matmul is bit-identical across backends (the pallas
   kernel computes exactly ``(x @ q.f32) * s``, the jnp path evaluates
   the same expression) and its custom VJP matches autodiff of the
   dequantized product.
3. Each q8 segment tracks its f32 twin within a drift bound tight enough
   that tiny-fixture greedy decode is token-identical (the bound the Rust
   differential gate pins per segment).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import ModelConfig
from compile.kernels.quant import (dequantize, q8_matmul,
                                   quantize_per_channel)

CFG = ModelConfig("unitq", d_model=16, n_layers=2, n_heads=2, vocab=32,
                  seq=12, batch=3, lora_rank=4, block_q=8, block_k=8,
                  block_n=8, xent_block_n=4, page_t=4)

# Per-segment drift bound (documented in DESIGN.md §15; it_quant.rs pins
# the tiny-fixture equivalent): max-abs error under 4% of the reference
# output's max magnitude. int8-chan keeps relative weight error under
# ~0.4% (1/254); the std-0.3 random weights here are far hotter than
# trained nets and compound to ~3% — and greedy argmax identity below is
# the sharp end-to-end check.
DRIFT = 4e-2


def assert_drift(got, want):
    got, want = np.asarray(got), np.asarray(want)
    bound = DRIFT * max(1.0, float(np.max(np.abs(want))))
    d = float(np.max(np.abs(got - want)))
    assert d < bound, f"q8 drift {d:.4g} exceeds bound {bound:.4g}"


def rand(key, shape, std=0.3):
    return std * jax.random.normal(jax.random.PRNGKey(key), shape,
                                   jnp.float32)


# ---------------------------------------------------------------------------
# 1. quantize/dequantize round-trip properties
# ---------------------------------------------------------------------------

def test_scale_is_per_output_channel_absmax():
    w = np.array([[1.0, -8.0], [-2.0, 4.0], [0.5, 0.0]], np.float32)
    q, s = quantize_per_channel(w)
    assert q.dtype == np.int8 and s.dtype == np.float32
    np.testing.assert_allclose(s, np.array([2.0, 8.0], np.float32) / 127.0)
    # the absmax element of every channel lands exactly on ±127
    assert q[1, 0] == -127 and q[0, 1] == -127


def test_round_trip_error_is_bounded_by_half_scale():
    w = np.asarray(rand(0, (64, 48)))
    q, s = quantize_per_channel(w)
    err = np.abs(dequantize(q, s) - w)
    assert np.all(err <= 0.5 * s[None, :] + 1e-7)


def test_rounding_is_half_even():
    # w/s = [63.5, 64.5, -63.5] must round to [64, 64, -64], not away
    # from zero — np.rint and Rust round_ties_even agree on this.
    s = np.float32(1.0 / 127.0)
    w = np.array([[63.5 * s, 64.5 * s, -63.5 * s],
                  [127.0 * s, 127.0 * s, 127.0 * s]], np.float32)
    q, _ = quantize_per_channel(w)
    assert list(q[0]) == [64, 64, -64]


def test_zero_channel_reproduces_exact_zeros():
    w = np.zeros((8, 3), np.float32)
    w[:, 0] = np.linspace(-1, 1, 8)
    q, s = quantize_per_channel(w)
    assert s[1] == 0.0 and s[2] == 0.0
    assert np.all(q[:, 1:] == 0)
    assert np.all(dequantize(q, s)[:, 1:] == 0.0)


def test_denormal_and_negative_extreme_channels():
    w = np.zeros((4, 2), np.float32)
    w[:, 0] = np.float32(1e-42)          # denormal channel
    w[0, 1] = np.float32(-3.4e38)        # negative extreme channel
    q, s = quantize_per_channel(w)
    assert np.all(np.isfinite(s))
    # denormal scales lose precision in the division (f32 denormal math),
    # but the result stays finite, sign-correct and within the int8 range
    assert 0 < q[0, 0] <= 127
    assert np.isfinite(dequantize(q, s)).all()
    assert q[0, 1] == -127
    np.testing.assert_allclose(dequantize(q, s)[0, 1], w[0, 1], rtol=1e-6)


def test_nan_and_inf_are_rejected():
    for bad in (np.nan, np.inf, -np.inf):
        w = np.ones((4, 4), np.float32)
        w[1, 2] = bad
        with pytest.raises(ValueError, match="NaN/Inf"):
            quantize_per_channel(w)


def test_non_2d_is_rejected():
    with pytest.raises(ValueError, match="2-D"):
        quantize_per_channel(np.ones((4,), np.float32))


# ---------------------------------------------------------------------------
# 2. fused-dequant matmul: backend parity + VJP
# ---------------------------------------------------------------------------

def test_kernel_matches_jnp_expression_bitwise():
    x = rand(1, (16, 24))
    q, s = quantize_per_channel(np.asarray(rand(2, (24, 40))))
    q, s = jnp.asarray(q), jnp.asarray(s)
    want = (x @ q.astype(jnp.float32)) * s
    got = q8_matmul(x, q, s, block_n=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_handles_3d_inputs():
    x = rand(3, (2, 6, 16))
    q, s = quantize_per_channel(np.asarray(rand(4, (16, 8))))
    got = q8_matmul(x, jnp.asarray(q), jnp.asarray(s), block_n=4)
    want = (x @ jnp.asarray(q).astype(jnp.float32)) * jnp.asarray(s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_vjp_matches_autodiff_of_dequantized_product():
    x = rand(5, (8, 16))
    q, s = quantize_per_channel(np.asarray(rand(6, (16, 12))))
    q, s = jnp.asarray(q), jnp.asarray(s)

    def via_kernel(x):
        return jnp.sum(jnp.sin(q8_matmul(x, q, s, block_n=4)))

    def via_jnp(x):
        return jnp.sum(jnp.sin((x @ q.astype(jnp.float32)) * s))

    gk = jax.grad(via_kernel)(x)
    gj = jax.grad(via_jnp)(x)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gj),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# 3. segment twins: q8 vs f32 drift, both backends
# ---------------------------------------------------------------------------

def make_params(key0=0):
    bp = []
    for l in range(CFG.n_layers):
        layer = []
        for i, (name, shape) in enumerate(CFG.block_param_shapes()):
            if name.startswith("g"):
                layer.append(jnp.ones(shape, jnp.float32))
            else:
                layer.append(rand(key0 + 10 * l + i, shape))
        bp.append(tuple(layer))
    emb = (rand(100, (CFG.vocab, CFG.d_model)),
           rand(101, (CFG.seq, CFG.d_model), 0.15))
    head = (jnp.ones((CFG.d_model,), jnp.float32),
            rand(102, (CFG.d_model, CFG.vocab)))
    return emb, bp, head


def qpair(w):
    q, s = quantize_per_channel(np.asarray(w))
    return jnp.asarray(q), jnp.asarray(s)


def quantize_block(p):
    """f32 8-tuple -> quantized 14-tuple (ABI order)."""
    g1, wq, wk, wv, wo, g2, w1, w2 = p
    out = [g1]
    for w in (wq, wk, wv, wo):
        out.extend(qpair(w))
    out.append(g2)
    for w in (w1, w2):
        out.extend(qpair(w))
    return tuple(out)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_block_fwd_q8_tracks_f32(backend):
    _, bp, _ = make_params()
    h = rand(7, (CFG.batch, CFG.seq, CFG.d_model), 0.5)
    f32 = model.block_fwd(h, *bp[0], cfg=CFG, backend=backend)
    q8 = model.block_fwd_q8(h, *quantize_block(bp[0]), cfg=CFG,
                            backend=backend)
    assert_drift(q8, f32)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_greedy_decode_is_token_identical(backend):
    """The headline differential: full-forward greedy over q8 segments
    equals the f32 path token-for-token on the unit fixture."""
    emb, bp, head = make_params()
    qemb = (*qpair(emb[0]), *qpair(emb[1]))
    qbp = [quantize_block(p) for p in bp]
    qhead = (head[0], *qpair(head[1]))
    prompt = [3, 14, 15]
    seq_f, seq_q = list(prompt), list(prompt)
    for _ in range(6):
        toks = jnp.array([seq_f + [0] * (CFG.seq - len(seq_f))] * CFG.batch,
                         jnp.int32)
        h = model.embed_fwd(toks, *emb, cfg=CFG)
        hq = model.embed_fwd_q8(toks, *qemb, cfg=CFG)
        for p, qp in zip(bp, qbp):
            h = model.block_fwd(h, *p, cfg=CFG, backend=backend)
            hq = model.block_fwd_q8(hq, *qp, cfg=CFG, backend=backend)
        lg = model.head_logits(h, *head, cfg=CFG, backend=backend)
        lq = model.head_logits_q8(hq, *qhead, cfg=CFG, backend=backend)
        pos = len(seq_f) - 1
        assert_drift(lq[0, pos], lg[0, pos])
        nf = int(jnp.argmax(lg[0, pos]))
        nq = int(jnp.argmax(lq[0, pos]))
        assert nf == nq, "greedy token diverged under int8"
        seq_f.append(nf)
        seq_q.append(nq)
    assert seq_f == seq_q


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_block_bwd_x_q8_grad_tracks_f32(backend):
    _, bp, _ = make_params()
    h = rand(8, (CFG.batch, CFG.seq, CFG.d_model), 0.5)
    dh = rand(9, (CFG.batch, CFG.seq, CFG.d_model), 0.5)
    g_f32 = model.block_bwd_x(dh, h, *bp[0], cfg=CFG, backend=backend)
    g_q8 = model.block_bwd_x_q8(dh, h, *quantize_block(bp[0]), cfg=CFG,
                                backend=backend)
    assert_drift(g_q8, g_f32)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_lora_q8_segments_track_f32(backend):
    _, bp, _ = make_params()
    h = rand(10, (CFG.batch, CFG.seq, CFG.d_model), 0.5)
    dh = rand(11, (CFG.batch, CFG.seq, CFG.d_model), 0.5)
    lora = []
    for nm, din, dout in [("q", 16, 16), ("k", 16, 16), ("v", 16, 16),
                          ("o", 16, 16), ("1", 16, 64), ("2", 64, 16)]:
        lora.append(rand(20 + len(lora), (din, CFG.lora_rank), 0.2))
        lora.append(jnp.zeros((CFG.lora_rank, dout), jnp.float32))
    # B = 0 would hide adapter drift; perturb it
    lora[1] = rand(40, (CFG.lora_rank, 16), 0.2)
    f32 = model.block_fwd_lora(h, *bp[0], *lora, cfg=CFG, backend=backend)
    q8 = model.block_fwd_lora_q8(h, *quantize_block(bp[0]), *lora, cfg=CFG,
                                 backend=backend)
    assert_drift(q8, f32)

    outs_f = model.block_bwd_lora(dh, h, *bp[0], *lora, cfg=CFG,
                                  backend=backend)
    outs_q = model.block_bwd_lora_q8(dh, h, *quantize_block(bp[0]), *lora,
                                     cfg=CFG, backend=backend)
    assert len(outs_f) == len(outs_q) == 13  # dh + 12 adapter grads
    for a, b in zip(outs_f, outs_q):
        assert_drift(b, a)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_head_q8_segments_track_f32(backend):
    _, _, head = make_params()
    qhead = (head[0], *qpair(head[1]))
    h = rand(12, (CFG.batch, CFG.seq, CFG.d_model), 0.5)
    tgt = jnp.array(np.random.RandomState(0).randint(
        0, CFG.vocab, (CFG.batch, CFG.seq)), jnp.int32)
    lf, dhf = model.head_fwd_bwd_x(h, *head, tgt, cfg=CFG, backend=backend)
    lq, dhq = model.head_fwd_bwd_x_q8(h, *qhead, tgt, cfg=CFG,
                                      backend=backend)
    assert_drift(lq, lf)
    assert_drift(dhq, dhf)
    lf2 = model.head_loss(h, *head, tgt, cfg=CFG, backend=backend)
    lq2 = model.head_loss_q8(h, *qhead, tgt, cfg=CFG, backend=backend)
    assert_drift(lq2, lf2)


@pytest.mark.parametrize("backend", ["jnp"])
def test_decode_step_q8_tracks_f32(backend):
    """Cached-decode twins: one step + logits, v1 packed state."""
    emb, bp, head = make_params()
    qemb = (*qpair(emb[0]), *qpair(emb[1]))
    qbp = [quantize_block(p) for p in bp]
    qhead = (head[0], *qpair(head[1]))
    b = CFG.batch
    state = jnp.zeros((b, model.decode_state_rows(CFG), CFG.d_model),
                      jnp.float32)
    tok = jnp.array([[3]] * b, jnp.int32)
    pidx = jnp.array([[0]] * b, jnp.int32)
    flat_bp = [w for p in bp for w in p]
    flat_qbp = [w for p in qbp for w in p]
    s_f = model.decode_step(tok, pidx, state, *emb, *flat_bp, cfg=CFG,
                            backend=backend)
    s_q = model.decode_step_q8(tok, pidx, state, *qemb, *flat_qbp, cfg=CFG,
                               backend=backend)
    lf = model.decode_logits(s_f, *head, cfg=CFG, backend=backend)
    lq = model.decode_logits_q8(s_q, *qhead, cfg=CFG, backend=backend)
    assert_drift(lq, lf)
    assert int(jnp.argmax(lf[0, 0])) == int(jnp.argmax(lq[0, 0]))
    # prefill twin
    h = model.embed_fwd(jnp.zeros((b, CFG.seq), jnp.int32), *emb, cfg=CFG)
    kv_f = model.prefill_kv(h, bp[0][0], bp[0][2], bp[0][3], cfg=CFG,
                            backend=backend)
    kv_q = model.prefill_kv_q8(h, qbp[0][0], *qbp[0][3:7], cfg=CFG,
                               backend=backend)
    assert_drift(kv_q, kv_f)
