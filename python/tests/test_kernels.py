"""L1 correctness: every Pallas kernel against its pure-jnp oracle in
``ref.py``, swept over shapes, tilings and edge cases.

All kernels run under interpret=True (float32-exact on CPU), so tolerances
are tight. These tests are the gate for `make artifacts`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.adamw import adamw_update, pack_hyper
from compile.kernels.flash_attention import flash_attention, mxu_utilization, vmem_bytes
from compile.kernels.rmsnorm import rmsnorm
from compile.kernels.softmax_xent import softmax_xent, xent_loss

RTOL, ATOL = 2e-5, 2e-5


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_SHAPES = [
    # (B, H, T, Dh, block_q, block_k)
    (1, 1, 8, 4, 8, 8),      # single tile
    (2, 2, 32, 16, 16, 8),   # uneven q/k tiles
    (1, 4, 64, 32, 16, 32),
    (2, 1, 33, 8, 16, 16),   # T not divisible by requested tile
    (1, 2, 128, 64, 128, 128),  # MXU-aligned
]


@pytest.mark.parametrize("b,h,t,d,bq,bk", ATTN_SHAPES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_forward(b, h, t, d, bq, bk, causal):
    q, k, v = rand(0, b, h, t, d), rand(1, b, h, t, d), rand(2, b, h, t, d)
    out = flash_attention(q, k, v, causal, None, bq, bk, True)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("b,h,t,d,bq,bk", ATTN_SHAPES[:4])
def test_flash_attention_backward(b, h, t, d, bq, bk):
    q, k, v = rand(3, b, h, t, d), rand(4, b, h, t, d), rand(5, b, h, t, d)
    do = rand(6, b, h, t, d)

    f = lambda q, k, v: (flash_attention(q, k, v, True, None, bq, bk, True) * do).sum()
    fr = lambda q, k, v: (ref.attention(q, k, v, causal=True) * do).sum()
    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b_, name in zip(g, gr, "qkv"):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4,
                                   err_msg=f"d{name}")


def test_flash_attention_scale_override():
    q, k, v = rand(7, 1, 1, 16, 8), rand(8, 1, 1, 16, 8), rand(9, 1, 1, 16, 8)
    out = flash_attention(q, k, v, True, 0.5, 8, 8, True)
    want = ref.attention(q, k, v, causal=True, sm_scale=0.5)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


def test_flash_attention_deterministic():
    q, k, v = rand(10, 1, 2, 32, 8), rand(11, 1, 2, 32, 8), rand(12, 1, 2, 32, 8)
    a = flash_attention(q, k, v, True, None, 16, 16, True)
    b = flash_attention(q, k, v, True, None, 16, 16, True)
    np.testing.assert_array_equal(a, b)


def test_vmem_model_monotone_in_tiles():
    small = vmem_bytes(t=256, d=64, block_q=64, block_k=64)
    big = vmem_bytes(t=256, d=64, block_q=128, block_k=128)
    assert small < big
    # the e2e100m kernel config must fit a 16 MiB VMEM budget
    assert vmem_bytes(t=256, d=64, block_q=128, block_k=128) < 16 * 2**20


def test_mxu_utilization_prefers_aligned_tiles():
    assert mxu_utilization(256, 128, 128, 128) == 1.0
    assert mxu_utilization(256, 64, 96, 96) < 1.0


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,block", [(4, 8, 4), (16, 32, 8), (33, 24, 16), (128, 128, 128)])
def test_rmsnorm_forward(n, d, block):
    x, g = rand(20, n, d), rand(21, d)
    out = rmsnorm(x, g, 1e-6, block, True)
    np.testing.assert_allclose(out, ref.rmsnorm(x, g), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("n,d,block", [(8, 16, 4), (32, 64, 16)])
def test_rmsnorm_backward(n, d, block):
    x, g, dy = rand(22, n, d), rand(23, d), rand(24, n, d)
    f = lambda x, g: (rmsnorm(x, g, 1e-6, block, True) * dy).sum()
    fr = lambda x, g: (ref.rmsnorm(x, g) * dy).sum()
    got = jax.grad(f, argnums=(0, 1))(x, g)
    want = jax.grad(fr, argnums=(0, 1))(x, g)
    for a, b, name in zip(got, want, ["dx", "dg"]):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4, err_msg=name)


def test_rmsnorm_3d_input():
    x, g = rand(25, 2, 6, 16), rand(26, 16)
    out = rmsnorm(x, g, 1e-6, 4, True)
    np.testing.assert_allclose(out, ref.rmsnorm(x, g), rtol=RTOL, atol=ATOL)


def test_rmsnorm_handles_tiny_values():
    x = jnp.full((4, 8), 1e-20, jnp.float32)
    g = jnp.ones((8,), jnp.float32)
    out = rmsnorm(x, g, 1e-6, 4, True)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# adamw
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,block", [(16, 8), (100, 32), (4096, 1024)])
@pytest.mark.parametrize("step", [1, 2, 50])
@pytest.mark.parametrize("wd", [0.0, 0.1])
def test_adamw_matches_ref(n, block, step, wd):
    p, g, m = rand(30, n), rand(31, n), rand(32, n)
    v = jnp.abs(rand(33, n))
    hyper = pack_hyper(1e-3, weight_decay=wd, step=step)
    got = adamw_update(p, g, m, v, hyper, block=block)
    want = ref.adamw(p, g, m, v, lr=1e-3, weight_decay=wd, step=step)
    for a, b, name in zip(got, want, ["p", "m", "v"]):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7, err_msg=name)


def test_adamw_zero_grad_only_decays():
    p = rand(34, 32)
    z = jnp.zeros((32,), jnp.float32)
    hyper = pack_hyper(0.1, weight_decay=0.5, step=1)
    p2, m2, v2 = adamw_update(p, z, z, z, hyper, block=16)
    np.testing.assert_allclose(p2, p - 0.1 * 0.5 * p, rtol=1e-6)
    np.testing.assert_array_equal(m2, z)


# ---------------------------------------------------------------------------
# softmax cross-entropy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,v,block", [(4, 8, 2), (16, 64, 8), (32, 128, 32)])
def test_xent_matches_ref(n, v, block):
    logits = rand(40, n, v)
    targets = jnp.arange(n, dtype=jnp.int32) % v
    l1, d1 = softmax_xent(logits, targets, block_n=block)
    l2, d2 = ref.softmax_xent(logits, targets)
    np.testing.assert_allclose(l1, l2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(d1, d2, rtol=RTOL, atol=ATOL)


def test_xent_ignore_index():
    logits = rand(41, 8, 16)
    targets = jnp.array([1, -1, 3, -1, 5, 6, -1, 0], jnp.int32)
    l1, d1 = softmax_xent(logits, targets, block_n=4)
    l2, d2 = ref.softmax_xent(logits, targets)
    np.testing.assert_allclose(l1, l2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(d1, d2, rtol=RTOL, atol=ATOL)
    # ignored rows have exactly zero gradient
    assert np.all(np.asarray(d1)[1] == 0.0)


def test_xent_all_ignored_is_finite():
    logits = rand(42, 4, 8)
    targets = jnp.full((4,), -1, jnp.int32)
    loss, dl = softmax_xent(logits, targets, block_n=4)
    assert np.isfinite(float(loss))
    assert np.all(np.asarray(dl) == 0.0)


def test_xent_loss_custom_vjp_grad():
    logits = rand(43, 8, 32)
    targets = jnp.arange(8, dtype=jnp.int32)
    g1 = jax.grad(lambda l: xent_loss(l, targets, 4, True))(logits)
    g2 = ref.softmax_xent(logits, targets)[1]
    np.testing.assert_allclose(g1, g2, rtol=RTOL, atol=ATOL)


def test_xent_extreme_logits_stable():
    logits = jnp.array([[1e4, -1e4, 0.0, 5.0]] * 4, jnp.float32)
    targets = jnp.array([0, 1, 2, 3], jnp.int32)
    loss, dl = softmax_xent(logits, targets, block_n=4)
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(dl)).all()
