"""AOT exporter contract tests: HLO text is parseable-shaped, the manifest
signature matches the lowered functions, and partial re-exports merge
rather than clobber.
"""

import json
import os

import pytest

from compile import aot
from compile.configs import CONFIGS, ModelConfig

UNIT = ModelConfig("unitaot", d_model=16, n_layers=2, n_heads=2, vocab=32,
                   seq=8, batch=1, lora_rank=4, block_q=8, block_k=8,
                   block_n=8, xent_block_n=4, page_t=4)


def test_registry_covers_all_segments():
    reg = aot.segment_registry(UNIT, "jnp")
    names = set(reg)
    expected = {
        "embed_fwd", "embed_bwd", "block_fwd", "block_bwd_full",
        "block_bwd_x", "block_fwd_lora", "block_bwd_lora", "head_fwd_bwd",
        "head_fwd_bwd_x", "head_loss", "head_logits", "adamw_update",
        "prefill_kv", "pack_state", "decode_step", "decode_logits",
        "paged_step", "paged_logits", "paged_scatter",
        # q8 twins: frozen-base int8 variants (DESIGN.md §15)
        "embed_fwd_q8", "block_fwd_q8", "block_bwd_x_q8",
        "block_fwd_lora_q8", "block_bwd_lora_q8", "head_fwd_bwd_x_q8",
        "head_loss_q8", "head_logits_q8", "prefill_kv_q8",
        "decode_step_q8", "decode_logits_q8", "paged_step_q8",
        "paged_logits_q8",
    }
    assert names == expected


def test_operand_orders_match_config_abi():
    reg = aot.segment_registry(UNIT, "jnp")
    _, specs = reg["block_fwd"]
    # h + 8 block params
    assert len(specs) == 1 + len(UNIT.block_param_shapes())
    for spec, (_, shape) in zip(specs[1:], UNIT.block_param_shapes()):
        assert tuple(spec.shape) == tuple(shape)
    _, specs = reg["block_bwd_lora"]
    assert len(specs) == 2 + 8 + 12


def test_export_writes_hlo_text_and_manifest(tmp_path):
    aot.export_config(UNIT, str(tmp_path), ["jnp"],
                      segments={"embed_fwd", "head_loss", "head_fwd_bwd"})
    d = tmp_path / "unitaot"
    hlo = (d / "embed_fwd.jnp.hlo.txt").read_text()
    assert hlo.startswith("HloModule"), "must be HLO text, not a proto"
    man = json.loads((d / "manifest.json").read_text())
    assert man["config"]["d_model"] == 16
    assert man["segments"]["embed_fwd.jnp"]["operands"][0]["dtype"] == "int32"
    out = man["segments"]["head_loss.jnp"]["outputs"]
    assert out == [{"shape": [], "dtype": "float32"}]
    # single-output segments export a bare root (device-chainable),
    # multi-output segments stay tuple-rooted
    assert man["segments"]["embed_fwd.jnp"]["tuple_root"] is False
    assert man["segments"]["head_loss.jnp"]["tuple_root"] is False
    assert man["segments"]["head_fwd_bwd.jnp"]["tuple_root"] is True


def test_skipped_reexport_keeps_on_disk_root_convention(tmp_path):
    # A legacy artifact (tuple-rooted, no manifest flag) re-exported
    # without --force must stay flagged tuple_root=true: the manifest has
    # to describe the HLO actually on disk, not what a fresh export would
    # produce.
    aot.export_config(UNIT, str(tmp_path), ["jnp"], segments={"block_fwd"})
    mpath = tmp_path / "unitaot" / "manifest.json"
    man = json.loads(mpath.read_text())
    assert man["segments"]["block_fwd.jnp"]["tuple_root"] is False
    # simulate a legacy manifest entry for the same on-disk file
    man["segments"]["block_fwd.jnp"].pop("tuple_root")
    mpath.write_text(json.dumps(man))
    aot.export_config(UNIT, str(tmp_path), ["jnp"], segments={"block_fwd"})
    man = json.loads(mpath.read_text())
    assert man["segments"]["block_fwd.jnp"]["tuple_root"] is True
    # --force re-lowers and reclaims the bare root
    aot.export_config(UNIT, str(tmp_path), ["jnp"], segments={"block_fwd"},
                      force=True)
    man = json.loads(mpath.read_text())
    assert man["segments"]["block_fwd.jnp"]["tuple_root"] is False


def test_orphaned_hlo_without_manifest_entry_is_relowered(tmp_path, capsys):
    # An HLO file whose manifest entry is gone (deleted/corrupt manifest)
    # has an unknowable root convention: the exporter must re-lower it
    # rather than guess, so the manifest always describes the real file.
    aot.export_config(UNIT, str(tmp_path), ["jnp"], segments={"block_fwd"})
    mpath = tmp_path / "unitaot" / "manifest.json"
    mpath.unlink()
    capsys.readouterr()
    aot.export_config(UNIT, str(tmp_path), ["jnp"], segments={"block_fwd"})
    out = capsys.readouterr().out
    assert "[ok]" in out and "[skip]" not in out
    man = json.loads(mpath.read_text())
    assert man["segments"]["block_fwd.jnp"]["tuple_root"] is False


def test_decode_segments_are_bare_rooted_and_version_the_manifest(tmp_path):
    decode = {"prefill_kv", "pack_state", "decode_step", "decode_logits"}
    aot.export_config(UNIT, str(tmp_path), ["jnp"], segments=decode)
    man = json.loads((tmp_path / "unitaot" / "manifest.json").read_text())
    assert man["decode_abi"] == 1
    t, d, L = UNIT.seq, UNIT.d_model, UNIT.n_layers
    ds = man["segments"]["decode_step.jnp"]
    # single-output -> bare root -> device-chainable cache state
    assert ds["tuple_root"] is False
    assert ds["outputs"] == [
        {"shape": [UNIT.batch, L * 2 * t + 1, d], "dtype": "float32"}]
    # tok, pidx, state, emb, pos, then L x 8 block params
    assert len(ds["operands"]) == 5 + 8 * L
    assert ds["operands"][0] == {"shape": [UNIT.batch, 1], "dtype": "int32"}
    kv = man["segments"]["prefill_kv.jnp"]
    assert kv["tuple_root"] is False
    assert kv["outputs"][0]["shape"] == [UNIT.batch, 2 * t, d]
    assert man["segments"]["decode_logits.jnp"]["outputs"][0]["shape"] == \
        [UNIT.batch, 1, UNIT.vocab]


def test_paged_segments_stamp_abi_v2_and_geometry(tmp_path):
    # v1-only export stays abi 1 (covered above); completing the paged set
    # upgrades the same manifest to abi 2 and records the pool geometry
    from compile import model as mdl

    v1 = {"prefill_kv", "pack_state", "decode_step", "decode_logits"}
    aot.export_config(UNIT, str(tmp_path), ["jnp"], segments=v1)
    man = json.loads((tmp_path / "unitaot" / "manifest.json").read_text())
    assert man["decode_abi"] == 1 and "paged" not in man

    paged = {"paged_step", "paged_logits", "paged_scatter"}
    aot.export_config(UNIT, str(tmp_path), ["jnp"], segments=paged)
    man = json.loads((tmp_path / "unitaot" / "manifest.json").read_text())
    assert man["decode_abi"] == 2
    assert man["paged"] == {
        "page_t": UNIT.page_t,
        "pages_per_row": UNIT.pages_per_row,
        "page_n": UNIT.page_n,
        "state_rows": mdl.paged_state_rows(UNIT),
    }
    rows, d = mdl.paged_state_rows(UNIT), UNIT.d_model
    ps = man["segments"]["paged_step.jnp"]
    # single-output -> bare root -> device-chainable paged state
    assert ps["tuple_root"] is False
    assert ps["outputs"] == [{"shape": [rows, d], "dtype": "float32"}]
    # tok, pidx, table, state, emb, pos, then L x 8 block params
    assert len(ps["operands"]) == 6 + 8 * UNIT.n_layers
    assert ps["operands"][2] == {
        "shape": [UNIT.batch, UNIT.pages_per_row], "dtype": "int32"}
    sc = man["segments"]["paged_scatter.jnp"]
    assert sc["tuple_root"] is False
    assert len(sc["operands"]) == 2 + UNIT.n_layers
    assert man["segments"]["paged_logits.jnp"]["outputs"][0]["shape"] == \
        [UNIT.batch, 1, UNIT.vocab]


def test_partial_export_without_decode_segments_claims_no_decode_abi(tmp_path):
    # a manifest that doesn't carry the full decode segment set must not
    # advertise the ABI (the Rust gate falls back to the legacy path)
    aot.export_config(UNIT, str(tmp_path), ["jnp"], segments={"embed_fwd"})
    man = json.loads((tmp_path / "unitaot" / "manifest.json").read_text())
    assert man["decode_abi"] == 0


def test_reexport_merges_manifest(tmp_path):
    aot.export_config(UNIT, str(tmp_path), ["jnp"], segments={"embed_fwd"})
    aot.export_config(UNIT, str(tmp_path), ["jnp"], segments={"head_logits"})
    man = json.loads((tmp_path / "unitaot" / "manifest.json").read_text())
    assert "embed_fwd.jnp" in man["segments"]
    assert "head_logits.jnp" in man["segments"]


def test_skip_existing_unless_forced(tmp_path, capsys):
    aot.export_config(UNIT, str(tmp_path), ["jnp"], segments={"embed_fwd"})
    capsys.readouterr()
    aot.export_config(UNIT, str(tmp_path), ["jnp"], segments={"embed_fwd"})
    assert "[skip]" in capsys.readouterr().out


def test_configs_are_well_formed():
    for name, cfg in CONFIGS.items():
        assert cfg.d_model % cfg.n_heads == 0, name
        assert cfg.n_params() > 0
        assert cfg.lora_rank < cfg.d_model
        # artifact batch/seq must be positive and modest for CPU
        assert 1 <= cfg.batch <= 16
