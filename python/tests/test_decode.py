"""Decode-segment correctness: the batched KV-cached decode path
(prefill_kv -> pack_state -> decode_step* -> decode_logits) must reproduce
the full-forward greedy path token-for-token on a mixed-length batch —
the same contract `rust/tests/it_decode.rs` enforces end-to-end through
the compiled artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import ModelConfig

CFG = ModelConfig("unitdec", d_model=16, n_layers=2, n_heads=2, vocab=32,
                  seq=12, batch=3, lora_rank=4, block_q=8, block_k=8,
                  block_n=8, xent_block_n=4, page_t=4)

PAD, EOS = 0, 2


def rand(key, shape, std=0.05):
    return std * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def make_params(key0=0):
    bp = []
    for l in range(CFG.n_layers):
        layer = []
        for i, (name, shape) in enumerate(CFG.block_param_shapes()):
            if name.startswith("g"):
                layer.append(jnp.ones(shape, jnp.float32))
            else:
                layer.append(rand(key0 + 10 * l + i, shape, std=0.3))
        bp.append(tuple(layer))
    emb = (rand(100, (CFG.vocab, CFG.d_model), 0.3),
           rand(101, (CFG.seq, CFG.d_model), 0.15))
    head = (jnp.ones((CFG.d_model,), jnp.float32),
            rand(102, (CFG.d_model, CFG.vocab), 0.3))
    return emb, bp, head


def full_logits(tokens, emb, bp, head, backend):
    """The legacy path: embed -> block_fwd^L -> head_logits. [B,T,V]."""
    h = model.embed_fwd(tokens, *emb, cfg=CFG)
    for p in bp:
        h = model.block_fwd(h, *p, cfg=CFG, backend=backend)
    return model.head_logits(h, *head, cfg=CFG, backend=backend)


def legacy_greedy(prompt, emb, bp, head, max_new, backend):
    """Mirror of rust `greedy_complete_legacy`: one row, O(T) full forwards."""
    seq = list(prompt)
    out = []
    for _ in range(max_new):
        if len(seq) >= CFG.seq:
            break
        row = seq + [PAD] * (CFG.seq - len(seq))
        tokens = jnp.array([row] * CFG.batch, jnp.int32)
        logits = full_logits(tokens, emb, bp, head, backend)
        nxt = int(jnp.argmax(logits[0, len(seq) - 1]))
        if nxt == EOS:
            break
        seq.append(nxt)
        out.append(nxt)
    return out


def cached_greedy_batch(prompts, emb, bp, head, max_new, backend):
    """The serving path over one [B] batch of mixed-length prompts."""
    t_max = CFG.seq
    rows = [list(p) for p in prompts]
    assert len(rows) == CFG.batch and all(len(r) < t_max for r in rows)
    tokens = jnp.array(
        [r + [PAD] * (t_max - len(r)) for r in rows], jnp.int32)

    # prefill: block_fwd chain + per-layer prefill_kv on the block inputs
    h = model.embed_fwd(tokens, *emb, cfg=CFG)
    kvs = []
    for p in bp:
        g1, _, wk, wv = p[0], p[1], p[2], p[3]
        kvs.append(model.prefill_kv(h, g1, wk, wv, cfg=CFG, backend=backend))
        h = model.block_fwd(h, *p, cfg=CFG, backend=backend)
    logits = model.head_logits(h, *head, cfg=CFG, backend=backend)
    state = model.pack_state(*kvs, cfg=CFG)

    outs = [[] for _ in rows]
    alive = []
    for b, r in enumerate(rows):
        nxt = int(jnp.argmax(logits[b, len(r) - 1]))
        if nxt == EOS or max_new == 0:
            alive.append(False)
            continue
        r.append(nxt)
        outs[b].append(nxt)
        alive.append(len(outs[b]) < max_new and len(r) < t_max)

    flat_bp = [t for p in bp for t in p]
    steps = 0
    while any(alive):
        tok = jnp.array([[r[-1]] for r in rows], jnp.int32)
        pidx = jnp.array([[len(r) - 1] for r in rows], jnp.int32)
        state = model.decode_step(tok, pidx, state, *emb, *flat_bp,
                                  cfg=CFG, backend=backend)
        lg = model.decode_logits(state, *head, cfg=CFG, backend=backend)
        steps += 1
        for b, r in enumerate(rows):
            if not alive[b]:
                continue
            nxt = int(jnp.argmax(lg[b, 0]))
            if nxt == EOS:
                alive[b] = False
                continue
            r.append(nxt)
            outs[b].append(nxt)
            alive[b] = len(outs[b]) < max_new and len(r) < t_max
    return outs, steps


def test_shapes():
    emb, bp, head = make_params()
    t = CFG.seq
    h = rand(1, (CFG.batch, t, CFG.d_model), 1.0)
    kv = model.prefill_kv(h, bp[0][0], bp[0][2], bp[0][3], cfg=CFG,
                          backend="jnp")
    assert kv.shape == (CFG.batch, 2 * t, CFG.d_model)
    state = model.pack_state(*[kv] * CFG.n_layers, cfg=CFG)
    assert state.shape == (CFG.batch, model.decode_state_rows(CFG),
                           CFG.d_model)
    tok = jnp.zeros((CFG.batch, 1), jnp.int32)
    flat_bp = [x for p in bp for x in p]
    state2 = model.decode_step(tok, tok, state, *emb, *flat_bp, cfg=CFG,
                               backend="jnp")
    assert state2.shape == state.shape
    lg = model.decode_logits(state2, *head, cfg=CFG, backend="jnp")
    assert lg.shape == (CFG.batch, 1, CFG.vocab)
    assert np.isfinite(np.asarray(lg)).all()


def test_prefill_kv_matches_block_internals():
    """K/V from prefill_kv == the k/v a full block computes for the same h."""
    from compile.kernels import ref
    emb, bp, _ = make_params()
    h = rand(2, (CFG.batch, CFG.seq, CFG.d_model), 1.0)
    g1, _, wk, wv = bp[0][0], bp[0][1], bp[0][2], bp[0][3]
    kv = model.prefill_kv(h, g1, wk, wv, cfg=CFG, backend="jnp")
    x = ref.rmsnorm(h, g1)
    np.testing.assert_allclose(kv[:, :CFG.seq], x @ wk, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(kv[:, CFG.seq:], x @ wv, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_decode_step_matches_full_forward_logits(backend):
    """After prefill + one decode_step, decode_logits must equal the full
    forward's logits at the new position (numerically, not just argmax)."""
    emb, bp, head = make_params()
    t_max = CFG.seq
    lens = [5, 3, 7]
    rows = [[1] + [(7 * i + b) % (CFG.vocab - 5) + 5 for i in range(n - 1)]
            for b, n in enumerate(lens)]
    tokens = jnp.array([r + [PAD] * (t_max - len(r)) for r in rows],
                       jnp.int32)

    h = model.embed_fwd(tokens, *emb, cfg=CFG)
    kvs = []
    for p in bp:
        kvs.append(model.prefill_kv(h, p[0], p[2], p[3], cfg=CFG,
                                    backend=backend))
        h = model.block_fwd(h, *p, cfg=CFG, backend=backend)
    state = model.pack_state(*kvs, cfg=CFG)

    # append one fixed token per row, decode it through the cache
    new_tok = [9, 11, 13]
    flat_bp = [x for p in bp for x in p]
    tok = jnp.array([[v] for v in new_tok], jnp.int32)
    pidx = jnp.array([[n] for n in lens], jnp.int32)
    state = model.decode_step(tok, pidx, state, *emb, *flat_bp, cfg=CFG,
                              backend=backend)
    lg = model.decode_logits(state, *head, cfg=CFG, backend=backend)

    # reference: full forward over the extended rows
    for b, r in enumerate(rows):
        r.append(new_tok[b])
    tokens2 = jnp.array([r + [PAD] * (t_max - len(r)) for r in rows],
                        jnp.int32)
    ref_lg = full_logits(tokens2, emb, bp, head, backend)
    for b, n in enumerate(lens):
        np.testing.assert_allclose(
            lg[b, 0], ref_lg[b, n], rtol=2e-4, atol=2e-5,
            err_msg=f"row {b} (backend {backend})")


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_cached_greedy_matches_legacy_token_for_token(backend):
    emb, bp, head = make_params(key0=4)
    prompts = [[1, 6, 7], [1, 9, 10, 11, 12], [1, 5]]
    max_new = 6
    got, steps = cached_greedy_batch(prompts, emb, bp, head, max_new, backend)
    assert steps <= max_new
    for b, p in enumerate(prompts):
        want = legacy_greedy(p, emb, bp, head, max_new, backend)
        assert got[b] == want, f"row {b} diverged (backend {backend})"


# ---------------------------------------------------------------------------
# Paged cache (decode ABI v2): the paged segments must be value-for-value
# the v1 packed path — same prefill, page-indirect storage.
# ---------------------------------------------------------------------------

def default_table():
    """Each row owns a contiguous run of pages; page 0 stays scratch."""
    p = CFG.pages_per_row
    return jnp.array([[1 + b * p + j for j in range(p)]
                      for b in range(CFG.batch)], jnp.int32)


def paged_prefill(prompts, emb, bp, head, backend, table):
    """v1 prompt pipeline + paged_scatter; returns (rows, logits, state)."""
    t_max = CFG.seq
    rows = [list(p) for p in prompts]
    tokens = jnp.array([r + [PAD] * (t_max - len(r)) for r in rows],
                       jnp.int32)
    h = model.embed_fwd(tokens, *emb, cfg=CFG)
    kvs = []
    for p in bp:
        kvs.append(model.prefill_kv(h, p[0], p[2], p[3], cfg=CFG,
                                    backend=backend))
        h = model.block_fwd(h, *p, cfg=CFG, backend=backend)
    logits = model.head_logits(h, *head, cfg=CFG, backend=backend)
    state = jnp.zeros((model.paged_state_rows(CFG), CFG.d_model),
                      jnp.float32)
    state = model.paged_scatter(state, table, *kvs, cfg=CFG)
    return rows, logits, state


def paged_greedy_batch(prompts, emb, bp, head, max_new, backend, table=None):
    """`cached_greedy_batch`, but over the paged state."""
    t_max = CFG.seq
    if table is None:
        table = default_table()
    rows, logits, state = paged_prefill(prompts, emb, bp, head, backend,
                                        table)
    outs = [[] for _ in rows]
    alive = []
    for b, r in enumerate(rows):
        nxt = int(jnp.argmax(logits[b, len(r) - 1]))
        if nxt == EOS or max_new == 0:
            alive.append(False)
            continue
        r.append(nxt)
        outs[b].append(nxt)
        alive.append(len(outs[b]) < max_new and len(r) < t_max)

    flat_bp = [t for p in bp for t in p]
    steps = 0
    while any(alive):
        tok = jnp.array([[r[-1]] for r in rows], jnp.int32)
        pidx = jnp.array([[len(r) - 1] for r in rows], jnp.int32)
        state = model.paged_step(tok, pidx, table, state, *emb, *flat_bp,
                                 cfg=CFG, backend=backend)
        lg = model.paged_logits(state, *head, cfg=CFG, backend=backend)
        steps += 1
        for b, r in enumerate(rows):
            if not alive[b]:
                continue
            nxt = int(jnp.argmax(lg[b, 0]))
            if nxt == EOS:
                alive[b] = False
                continue
            r.append(nxt)
            outs[b].append(nxt)
            alive[b] = len(outs[b]) < max_new and len(r) < t_max
    return outs, steps, state


def test_paged_shapes():
    emb, bp, head = make_params()
    rows = model.paged_state_rows(CFG)
    assert rows == CFG.n_layers * 2 * CFG.page_n * CFG.page_t + CFG.batch
    state = jnp.zeros((rows, CFG.d_model), jnp.float32)
    kv = rand(21, (CFG.batch, 2 * CFG.seq, CFG.d_model), 0.3)
    table = default_table()
    state = model.paged_scatter(state, table, *[kv] * CFG.n_layers, cfg=CFG)
    assert state.shape == (rows, CFG.d_model)
    tok = jnp.zeros((CFG.batch, 1), jnp.int32)
    flat_bp = [x for p in bp for x in p]
    state2 = model.paged_step(tok, tok, table, state, *emb, *flat_bp,
                              cfg=CFG, backend="jnp")
    assert state2.shape == state.shape
    lg = model.paged_logits(state2, *head, cfg=CFG, backend="jnp")
    assert lg.shape == (CFG.batch, 1, CFG.vocab)
    assert np.isfinite(np.asarray(lg)).all()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_paged_step_matches_full_forward_logits(backend):
    """Paged prefill + one paged_step must equal the full forward's logits
    at the new position — the v2 mirror of the v1 test above."""
    emb, bp, head = make_params()
    t_max = CFG.seq
    lens = [5, 3, 7]
    rows = [[1] + [(7 * i + b) % (CFG.vocab - 5) + 5 for i in range(n - 1)]
            for b, n in enumerate(lens)]
    table = default_table()
    rows, _, state = paged_prefill(rows, emb, bp, head, backend, table)

    new_tok = [9, 11, 13]
    flat_bp = [x for p in bp for x in p]
    tok = jnp.array([[v] for v in new_tok], jnp.int32)
    pidx = jnp.array([[n] for n in lens], jnp.int32)
    state = model.paged_step(tok, pidx, table, state, *emb, *flat_bp,
                             cfg=CFG, backend=backend)
    lg = model.paged_logits(state, *head, cfg=CFG, backend=backend)

    for b, r in enumerate(rows):
        r.append(new_tok[b])
    tokens2 = jnp.array([r + [PAD] * (t_max - len(r)) for r in rows],
                        jnp.int32)
    ref_lg = full_logits(tokens2, emb, bp, head, backend)
    for b, n in enumerate(lens):
        np.testing.assert_allclose(
            lg[b, 0], ref_lg[b, n], rtol=2e-4, atol=2e-5,
            err_msg=f"row {b} (backend {backend})")


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_paged_greedy_matches_packed_greedy_token_for_token(backend):
    emb, bp, head = make_params(key0=4)
    prompts = [[1, 6, 7], [1, 9, 10, 11, 12], [1, 5]]
    max_new = 6
    want, _ = cached_greedy_batch(prompts, emb, bp, head, max_new, backend)
    got, steps, _ = paged_greedy_batch(prompts, emb, bp, head, max_new,
                                       backend)
    assert steps <= max_new
    assert got == want, f"paged vs packed diverged (backend {backend})"


def test_paged_decode_is_invariant_to_physical_page_placement():
    """Only the table order is semantic: scrambling which physical pages
    back each row must not change a single token."""
    emb, bp, head = make_params(key0=7)
    prompts = [[1, 6, 7], [1, 9, 10, 11, 12], [1, 5]]
    a, _, _ = paged_greedy_batch(prompts, emb, bp, head, 5, "jnp")
    # same rows, physically scattered across the pool in reverse
    p, n = CFG.pages_per_row, CFG.page_n
    scrambled = jnp.array(
        [[n - 1 - (b * p + j) for j in range(p)] for b in range(CFG.batch)],
        jnp.int32)
    b, _, _ = paged_greedy_batch(prompts, emb, bp, head, 5, "jnp",
                                 table=scrambled)
    assert a == b


def test_paged_shared_prefix_pages_serve_both_rows():
    """Rows 0 and 1 share their full first page of prompt; aliasing row 1's
    table onto row 0's physical page must reproduce the unaliased decode
    bit-for-bit and leave the shared page read-only under decode."""
    emb, bp, head = make_params(key0=9)
    bt = CFG.page_t
    shared = [1, 6, 7, 9]          # exactly one full page
    assert len(shared) == bt
    prompts = [shared + [3, 4], shared + [3, 4], [1, 5]]
    want, _, _ = paged_greedy_batch(prompts, emb, bp, head, 4, "jnp")

    table = np.asarray(default_table()).copy()
    table[1, 0] = table[0, 0]      # row 1 adopts row 0's prefix page
    aliased = jnp.array(table, jnp.int32)
    got, _, state = paged_greedy_batch(prompts, emb, bp, head, 4, "jnp",
                                       table=aliased)
    assert got == want
    assert got[0] == got[1]        # identical prompts, identical rows

    # the shared physical page still holds exactly the prefix K/V: decode
    # never wrote into it (all writes land at positions >= len(prompt))
    _, _, reference = paged_prefill(prompts, emb, bp, head, "jnp", aliased)
    g = int(table[0, 0])
    for half in range(2 * CFG.n_layers):
        rows_ = slice((half * CFG.page_n + g) * bt,
                      (half * CFG.page_n + g + 1) * bt)
        np.testing.assert_array_equal(
            np.asarray(state[rows_]), np.asarray(reference[rows_]),
            err_msg=f"shared page mutated (layer-half {half})")


def test_paged_write_is_idempotent():
    """Frozen-row replay (drained rows in a live batch) must not drift."""
    emb, bp, _ = make_params()
    kv = rand(22, (CFG.batch, 2 * CFG.seq, CFG.d_model), 0.3)
    table = default_table()
    state = jnp.zeros((model.paged_state_rows(CFG), CFG.d_model),
                      jnp.float32)
    state = model.paged_scatter(state, table, *[kv] * CFG.n_layers, cfg=CFG)
    flat_bp = [x for p in bp for x in p]
    tok = jnp.array([[5], [6], [7]], jnp.int32)
    pidx = jnp.array([[2], [4], [1]], jnp.int32)
    s1 = model.paged_step(tok, pidx, table, state, *emb, *flat_bp, cfg=CFG,
                          backend="jnp")
    s2 = model.paged_step(tok, pidx, table, s1, *emb, *flat_bp, cfg=CFG,
                          backend="jnp")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_cache_write_is_idempotent():
    """Re-running decode_step with the same (tok, pidx) — the frozen-row
    convention for finished rows in a live batch — must not drift."""
    emb, bp, head = make_params()
    kv = rand(20, (CFG.batch, 2 * CFG.seq, CFG.d_model), 0.3)
    state = model.pack_state(*[kv] * CFG.n_layers, cfg=CFG)
    flat_bp = [x for p in bp for x in p]
    tok = jnp.array([[5], [6], [7]], jnp.int32)
    pidx = jnp.array([[2], [4], [1]], jnp.int32)
    s1 = model.decode_step(tok, pidx, state, *emb, *flat_bp, cfg=CFG,
                           backend="jnp")
    s2 = model.decode_step(tok, pidx, s1, *emb, *flat_bp, cfg=CFG,
                           backend="jnp")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
