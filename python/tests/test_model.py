"""L2 correctness: segment functions compose to the same numbers as the
monolithic reference model, backward segments match autodiff of the forward
composition, and the pallas/jnp backends agree.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import ModelConfig

CFG = ModelConfig("unit", d_model=16, n_layers=2, n_heads=2, vocab=32,
                  seq=8, batch=2, lora_rank=4, block_q=8, block_k=8,
                  block_n=8, xent_block_n=4)


def rand(key, shape, std=0.05):
    return std * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def make_params(key0=0):
    bp = []
    for l in range(CFG.n_layers):
        layer = []
        for i, (name, shape) in enumerate(CFG.block_param_shapes()):
            if name.startswith("g"):
                layer.append(jnp.ones(shape, jnp.float32))
            else:
                layer.append(rand(key0 + 10 * l + i, shape))
        bp.append(tuple(layer))
    emb = (rand(100, (CFG.vocab, CFG.d_model)), rand(101, (CFG.seq, CFG.d_model)))
    head = (jnp.ones((CFG.d_model,), jnp.float32), rand(102, (CFG.d_model, CFG.vocab)))
    return emb, bp, head


def make_batch(key=7):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    tokens = jax.random.randint(k1, (CFG.batch, CFG.seq), 0, CFG.vocab, jnp.int32)
    targets = jax.random.randint(k2, (CFG.batch, CFG.seq), -1, CFG.vocab, jnp.int32)
    return tokens, targets


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_block_fwd_shapes(backend):
    emb, bp, head = make_params()
    h = rand(1, (CFG.batch, CFG.seq, CFG.d_model), 1.0)
    out = model.block_fwd(h, *bp[0], cfg=CFG, backend=backend)
    assert out.shape == h.shape
    assert np.isfinite(np.asarray(out)).all()


def test_backends_agree_on_full_loss():
    emb, bp, head = make_params()
    tokens, targets = make_batch()
    l1 = model.model_loss(tokens, targets, emb, bp, head, CFG, backend="jnp")
    l2 = model.model_loss(tokens, targets, emb, bp, head, CFG, backend="pallas")
    np.testing.assert_allclose(l1, l2, rtol=2e-5, atol=2e-5)


def test_block_bwd_full_matches_autodiff():
    _, bp, _ = make_params()
    h = rand(2, (CFG.batch, CFG.seq, CFG.d_model), 1.0)
    dh_out = rand(3, (CFG.batch, CFG.seq, CFG.d_model), 1.0)

    grads = model.block_bwd_full(dh_out, h, *bp[0], cfg=CFG, backend="jnp")
    # reference: autodiff of (block_fwd(h, θ) · dh_out)
    f = lambda h, *p: (model.block_fwd(h, *p, cfg=CFG, backend="jnp") * dh_out).sum()
    want = jax.grad(f, argnums=tuple(range(1 + len(bp[0]))))(h, *bp[0])
    assert len(grads) == len(want)
    for i, (a, b) in enumerate(zip(grads, want)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5,
                                   err_msg=f"grad {i}")


def test_block_bwd_x_matches_input_grad_only():
    _, bp, _ = make_params()
    h = rand(4, (CFG.batch, CFG.seq, CFG.d_model), 1.0)
    dh_out = rand(5, (CFG.batch, CFG.seq, CFG.d_model), 1.0)
    dh = model.block_bwd_x(dh_out, h, *bp[0], cfg=CFG, backend="jnp")
    full = model.block_bwd_full(dh_out, h, *bp[0], cfg=CFG, backend="jnp")
    np.testing.assert_allclose(dh, full[0], rtol=1e-5, atol=1e-6)


def test_head_fwd_bwd_matches_autodiff():
    _, _, head = make_params()
    tokens, targets = make_batch()
    h = rand(6, (CFG.batch, CFG.seq, CFG.d_model), 1.0)
    loss, dh, dgf, dwh = model.head_fwd_bwd(h, *head, targets, cfg=CFG, backend="jnp")
    f = lambda h, gf, wh: model.head_loss(h, gf, wh, targets, cfg=CFG, backend="jnp")
    lref = f(h, *head)
    np.testing.assert_allclose(loss, lref, rtol=1e-5)
    want = jax.grad(f, argnums=(0, 1, 2))(h, *head)
    for a, b, name in zip((dh, dgf, dwh), want, ["dh", "dgf", "dwh"]):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6, err_msg=name)


def test_head_fwd_bwd_x_matches_dh_only():
    _, _, head = make_params()
    tokens, targets = make_batch()
    h = rand(7, (CFG.batch, CFG.seq, CFG.d_model), 1.0)
    loss_x, dh_x = model.head_fwd_bwd_x(h, *head, targets, cfg=CFG, backend="jnp")
    loss, dh, _, _ = model.head_fwd_bwd(h, *head, targets, cfg=CFG, backend="jnp")
    np.testing.assert_allclose(loss_x, loss, rtol=1e-6)
    np.testing.assert_allclose(dh_x, dh, rtol=1e-5, atol=1e-7)


def test_embed_bwd_is_scatter_add():
    tokens = jnp.array([[0, 1, 1, 0, 2, 3, 3, 3]], jnp.int32)
    cfg = ModelConfig("u2", d_model=4, n_layers=1, n_heads=1, vocab=8,
                      seq=8, batch=1)
    dh = jnp.ones((1, 8, 4), jnp.float32)
    demb, dpos = model.embed_bwd(dh, tokens, cfg=cfg)
    # token 3 appears 3x, token 1 twice, token 0 twice, token 2 once
    np.testing.assert_allclose(demb[3], 3.0 * jnp.ones(4))
    np.testing.assert_allclose(demb[1], 2.0 * jnp.ones(4))
    np.testing.assert_allclose(demb[4], jnp.zeros(4))
    np.testing.assert_allclose(dpos, jnp.ones((8, 4)))


def test_embed_roundtrip_gradient():
    cfg = CFG
    emb, bp, head = make_params()
    tokens, targets = make_batch()
    # d(model_loss)/d(emb) via segments == via autodiff
    h = model.embed_fwd(tokens, *emb, cfg=cfg)

    def loss_from_h(h):
        out = h
        for p in bp:
            out = model.block_fwd(out, *p, cfg=cfg, backend="jnp")
        return model.head_loss(out, *head, targets, cfg=cfg, backend="jnp")

    dh = jax.grad(loss_from_h)(h)
    demb_seg, dpos_seg = model.embed_bwd(dh, tokens, cfg=cfg)

    def full(embw, posw):
        return model.model_loss(tokens, targets, (embw, posw), bp, head, cfg, "jnp")

    demb, dpos = jax.grad(full, argnums=(0, 1))(*emb)
    np.testing.assert_allclose(demb_seg, demb, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(dpos_seg, dpos, rtol=1e-4, atol=1e-6)


def test_lora_zero_b_matches_base():
    _, bp, _ = make_params()
    h = rand(8, (CFG.batch, CFG.seq, CFG.d_model), 1.0)
    lora = []
    for name, shape in CFG.lora_param_shapes():
        if name.startswith("a"):
            lora.append(rand(200 + len(lora), shape))
        else:
            lora.append(jnp.zeros(shape, jnp.float32))
    out_lora = model.block_fwd_lora(h, *bp[0], *lora, cfg=CFG, backend="jnp")
    out_base = model.block_fwd(h, *bp[0], cfg=CFG, backend="jnp")
    np.testing.assert_allclose(out_lora, out_base, rtol=1e-6, atol=1e-7)


def test_lora_bwd_grads_only_adapters():
    _, bp, _ = make_params()
    h = rand(9, (CFG.batch, CFG.seq, CFG.d_model), 1.0)
    dh_out = rand(10, (CFG.batch, CFG.seq, CFG.d_model), 1.0)
    lora = [rand(300 + i, s) for i, (_, s) in enumerate(CFG.lora_param_shapes())]
    grads = model.block_bwd_lora(dh_out, h, *bp[0], *lora, cfg=CFG, backend="jnp")
    # (dh_in, 12 adapter grads)
    assert len(grads) == 1 + len(lora)
    f = lambda h, *l: (model.block_fwd_lora(h, *bp[0], *l, cfg=CFG, backend="jnp") * dh_out).sum()
    want = jax.grad(f, argnums=tuple(range(1 + len(lora))))(h, *lora)
    for a, b in zip(grads, want):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


def test_head_logits_consistent_with_loss():
    _, _, head = make_params()
    tokens, _ = make_batch()
    h = rand(11, (CFG.batch, CFG.seq, CFG.d_model), 1.0)
    logits = model.head_logits(h, *head, cfg=CFG, backend="jnp")
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    # loss computed from logits equals head_loss
    targets = tokens
    from compile.kernels import ref
    l_manual, _ = ref.softmax_xent(
        logits.reshape(-1, CFG.vocab), targets.reshape(-1))
    l_seg = model.head_loss(h, *head, targets, cfg=CFG, backend="jnp")
    np.testing.assert_allclose(l_manual, l_seg, rtol=1e-5)
