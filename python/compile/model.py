"""Layer-2: the transformer segments of the LISA reproduction, in JAX.

The model is *not* lowered as one monolithic train step. LISA's wins come
from doing different work per transformer block per step, so each segment
below becomes its own HLO module and the Rust engine schedules them:

    embed_fwd -> block_fwd x L -> head_fwd_bwd -> block_bwd_{full|x} x L
              -> embed_bwd

Backward segments take the *block input* (not an activation stash) and
rematerialize the forward inside ``jax.vjp`` — per-block gradient
checkpointing, which keeps the artifact ABI to plain [B,T,D] tensors and
bounds activation memory at one residual per block (DESIGN.md §1).

Architecture: decoder-only pre-norm transformer — RMSNorm, causal flash
attention, GELU MLP (ratio 4), learned positional embeddings, untied LM
head, final RMSNorm in the head segment. Block parameter order (the ABI the
Rust side follows, see ``ModelConfig.block_param_shapes``):

    (g1, wq, wk, wv, wo, g2, w1, w2)

``backend`` selects the Layer-1 path: "pallas" routes rmsnorm/attention/
cross-entropy through the hand-written kernels (interpret=True), "jnp"
through the pure-jnp oracles — both lower to HLO and the pair is the
kernel-ablation axis in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.flash_attention import flash_attention
from .kernels.quant import q8_matmul
from .kernels.rmsnorm import rmsnorm
from .kernels.softmax_xent import xent_loss


# ---------------------------------------------------------------------------
# Primitive selection
# ---------------------------------------------------------------------------

def _norm(x, g, cfg: ModelConfig, backend: str):
    if backend == "pallas":
        return rmsnorm(x, g, 1e-6, cfg.block_n, True)
    return ref.rmsnorm(x, g)


def _attention(q, k, v, cfg: ModelConfig, backend: str):
    if backend == "pallas":
        return flash_attention(q, k, v, True, None, cfg.block_q, cfg.block_k,
                               True)
    return ref.attention(q, k, v, causal=True)


def _q8_lin(x, q, s, cfg: ModelConfig, backend: str):
    """Fused dequant linear over an int8 weight: ``(x @ q.f32) * s``.

    The exact expression is the cross-backend contract (DESIGN.md §15):
    ``(x @ q) * s`` and ``x @ (q * s)`` round differently in f32, and the
    Rust differential suites pin the former on both paths.
    """
    if backend == "pallas":
        return q8_matmul(x, q, s, cfg.block_n, True)
    return (x @ q.astype(jnp.float32)) * s


def _q8_embed(idx, q, s):
    """Gather-dequant an int8 embedding row block: q[idx].f32 * s."""
    return q[idx].astype(jnp.float32) * s


def _xent(logits, targets, cfg: ModelConfig, backend: str):
    if backend == "pallas":
        return xent_loss(logits, targets, cfg.xent_block_n, True)
    # ref path: scalar loss with standard autodiff
    valid = targets >= 0
    safe_t = jnp.where(valid, targets, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe_t[:, None], axis=-1)[:, 0]
    per_row = (lse - ll) * valid.astype(logits.dtype)
    denom = jnp.maximum(valid.sum().astype(logits.dtype), 1.0)
    return per_row.sum() / denom


# ---------------------------------------------------------------------------
# Segments
# ---------------------------------------------------------------------------

def embed_fwd(tokens, emb, pos, *, cfg: ModelConfig):
    """tokens i32[B,T] -> h f32[B,T,D] = emb[tokens] + pos."""
    return emb[tokens] + pos[None, :, :]


def embed_bwd(dh, tokens, *, cfg: ModelConfig):
    """Scatter-add token gradients. -> (demb [V,D], dpos [T,D])."""
    demb = jnp.zeros((cfg.vocab, cfg.d_model), jnp.float32)
    demb = demb.at[tokens].add(dh)
    dpos = jnp.sum(dh, axis=0)
    return demb, dpos


def _split_heads(x, cfg: ModelConfig):
    b, t, _ = x.shape
    return x.reshape(b, t, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x, cfg: ModelConfig):
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def block_core(h, params, cfg: ModelConfig, backend: str, lora=None):
    """One pre-norm transformer block. ``lora`` is the 12-tuple of adapters
    (aq,bq,ak,bk,av,bv,ao,bo,a1,b1,a2,b2) or None."""
    g1, wq, wk, wv, wo, g2, w1, w2 = params
    scale = cfg.lora_alpha / cfg.lora_rank if lora is not None else 0.0

    def lin(x, w, a, b):
        y = x @ w
        if lora is not None:
            y = y + (x @ a) @ b * scale
        return y

    if lora is None:
        la = [None] * 12
    else:
        la = lora
    x = _norm(h, g1, cfg, backend)
    q = _split_heads(lin(x, wq, la[0], la[1]), cfg)
    k = _split_heads(lin(x, wk, la[2], la[3]), cfg)
    v = _split_heads(lin(x, wv, la[4], la[5]), cfg)
    o = _merge_heads(_attention(q, k, v, cfg, backend), cfg)
    h1 = h + lin(o, wo, la[6], la[7])
    y = _norm(h1, g2, cfg, backend)
    ff = lin(jax.nn.gelu(lin(y, w1, la[8], la[9])), w2, la[10], la[11])
    return h1 + ff


def block_fwd(h, *params, cfg: ModelConfig, backend: str):
    return block_core(h, params, cfg, backend)


def block_bwd_full(dh_out, h_in, *params, cfg: ModelConfig, backend: str):
    """Rematerializing backward: -> (dh_in, dg1, dwq, ..., dw2)."""
    _, vjp = jax.vjp(lambda h, *p: block_core(h, p, cfg, backend),
                     h_in, *params)
    grads = vjp(dh_out)
    return grads  # (dh_in, *dparams)


def block_bwd_x(dh_out, h_in, *params, cfg: ModelConfig, backend: str):
    """Frozen-block backward: input gradient only (no dθ) -> dh_in.

    This is where LISA's FLOP savings are real: the dθ matmuls
    (dW = x^T @ dy per linear) are never emitted in this module.
    """
    _, vjp = jax.vjp(lambda h: block_core(h, params, cfg, backend), h_in)
    (dh_in,) = vjp(dh_out)
    return dh_in


def block_fwd_lora(h, *ps, cfg: ModelConfig, backend: str):
    params, lora = ps[:8], ps[8:]
    return block_core(h, params, cfg, backend, lora=lora)


def block_bwd_lora(dh_out, h_in, *ps, cfg: ModelConfig, backend: str):
    """LoRA backward: -> (dh_in, dA/dB x6 pairs); base weights get none."""
    params, lora = ps[:8], ps[8:]
    _, vjp = jax.vjp(
        lambda h, *l: block_core(h, params, cfg, backend, lora=l),
        h_in, *lora)
    return vjp(dh_out)  # (dh_in, *dlora)


def _head_loss(h, gf, wh, targets, cfg: ModelConfig, backend: str):
    x = _norm(h, gf, cfg, backend)
    logits = x.reshape(-1, cfg.d_model) @ wh
    return _xent(logits, targets.reshape(-1), cfg, backend)


def head_fwd_bwd(h, gf, wh, targets, *, cfg: ModelConfig, backend: str):
    """Fused head loss + grads: -> (loss, dh, dgf, dwh)."""
    loss, vjp = jax.vjp(
        lambda h, gf, wh: _head_loss(h, gf, wh, targets, cfg, backend),
        h, gf, wh)
    dh, dgf, dwh = vjp(jnp.float32(1.0))
    return loss, dh, dgf, dwh


def head_fwd_bwd_x(h, gf, wh, targets, *, cfg: ModelConfig, backend: str):
    """Frozen-head variant (LoRA mode): -> (loss, dh)."""
    loss, vjp = jax.vjp(
        lambda h: _head_loss(h, gf, wh, targets, cfg, backend), h)
    (dh,) = vjp(jnp.float32(1.0))
    return loss, dh


def head_loss(h, gf, wh, targets, *, cfg: ModelConfig, backend: str):
    """Eval-only loss (no grads)."""
    return _head_loss(h, gf, wh, targets, cfg, backend)


def head_logits(h, gf, wh, *, cfg: ModelConfig, backend: str):
    """Logits for eval / greedy decode / DoLa early exit: -> [B,T,V]."""
    x = _norm(h, gf, cfg, backend)
    return x @ wh


# ---------------------------------------------------------------------------
# Serving segments: batched KV-cached decode (DESIGN.md §9)
#
# The decode state of a whole model is ONE tensor of shape
# ``[B, L*2T + 1, D]``: for layer l, rows ``l*2T .. l*2T+T`` hold the K
# cache and rows ``l*2T+T .. (l+1)*2T`` the V cache (head-merged [T, D]
# layout), and the final row carries the last computed hidden state. A
# single tensor because the PJRT wrapper returns tuple-rooted outputs as
# one fused host literal — packing is what lets the cache chain between
# ``decode_step`` executions as a bare-rooted device buffer and never
# touch the host (the same ``tuple_root: false`` contract the residual
# stream uses).
#
# Attention inside ``decode_step`` is plain masked softmax over the cache
# (query length 1 — the flash kernel's causal [T, T] tiling does not
# apply); everything else routes through the backend primitives so the
# pallas/jnp pair stays the ablation axis.
# ---------------------------------------------------------------------------


def decode_state_rows(cfg: ModelConfig) -> int:
    """Second dim of the packed decode state: L*2T cache rows + 1 h row."""
    return cfg.n_layers * 2 * cfg.seq + 1


def prefill_kv(h, g1, wk, wv, *, cfg: ModelConfig, backend: str):
    """Per-layer prompt K/V: h [B,T,D] -> packed [B, 2T, D] (K rows then V).

    Runs next to ``block_fwd`` during prefill (same block input h), so the
    cached K/V are bit-identical to what the full forward computes
    internally for the prompt positions.
    """
    x = _norm(h, g1, cfg, backend)
    return jnp.concatenate([x @ wk, x @ wv], axis=1)


def pack_state(*kvs, cfg: ModelConfig):
    """Assemble the initial decode state from the L per-layer ``prefill_kv``
    outputs: -> [B, L*2T+1, D]. The final h row starts zeroed; every
    ``decode_step`` rewrites it."""
    assert len(kvs) == cfg.n_layers
    b = kvs[0].shape[0]
    h_row = jnp.zeros((b, 1, cfg.d_model), jnp.float32)
    return jnp.concatenate([*kvs, h_row], axis=1)


def _decode_attend(q, kc, vc, mask, cfg: ModelConfig):
    """Single-position attention over the cache. q [B,1,D], kc/vc [B,T,D],
    mask [B,T] (True = attendable) -> [B,1,D]."""
    b, t, _ = kc.shape
    hd = cfg.head_dim
    qh = q.reshape(b, 1, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    kh = kc.reshape(b, t, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    vh = vc.reshape(b, t, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    s = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) * (1.0 / (hd ** 0.5))
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vh)
    return o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.d_model)


def decode_step(tok, pidx, state, emb, pos, *bps, cfg: ModelConfig,
                backend: str):
    """One cached decode step for the whole model.

    tok/pidx: [B,1] i32 — the token each row just appended and its
    position; state: [B, L*2T+1, D] (see layout above). Embeds tok at
    pidx, then per layer writes the new K/V into the cache at pidx
    (one-hot blend — a fixed-shape scatter) and attends the single query
    over positions ``t <= pidx``. Returns the updated state with the
    final row holding the new last hidden state. Exactly one execution
    per generated token.
    """
    t_max = cfg.seq
    h = emb[tok] + pos[pidx]  # [B,1,D]
    onehot = jax.nn.one_hot(pidx[:, 0], t_max, dtype=jnp.float32)  # [B,T]
    mask = jax.lax.iota(jnp.int32, t_max)[None, :] <= pidx  # [B,T]
    rows = []
    for l in range(cfg.n_layers):
        g1, wq, wk, wv, wo, g2, w1, w2 = bps[8 * l:8 * (l + 1)]
        kc = state[:, l * 2 * t_max:l * 2 * t_max + t_max, :]
        vc = state[:, l * 2 * t_max + t_max:(l + 1) * 2 * t_max, :]
        x = _norm(h, g1, cfg, backend)
        q, k_new, v_new = x @ wq, x @ wk, x @ wv  # [B,1,D]
        keep = 1.0 - onehot[:, :, None]
        kc = kc * keep + k_new * onehot[:, :, None]
        vc = vc * keep + v_new * onehot[:, :, None]
        h1 = h + _decode_attend(q, kc, vc, mask, cfg) @ wo
        y = _norm(h1, g2, cfg, backend)
        h = h1 + jax.nn.gelu(y @ w1) @ w2
        rows.extend((kc, vc))
    return jnp.concatenate([*rows, h], axis=1)


def decode_logits(state, gf, wh, *, cfg: ModelConfig, backend: str):
    """Next-token logits from the state's final h row: -> [B, 1, V]."""
    h = state[:, -1:, :]
    x = _norm(h, gf, cfg, backend)
    return x @ wh


# ---------------------------------------------------------------------------
# Serving segments: paged K/V cache (decode ABI v2, DESIGN.md §12)
#
# Same single-tensor/bare-root trick as v1, different geometry: the state is
# ``[L*2*N*bt + B, D]`` — per layer one K pool and one V pool of N fixed
# pages x ``bt = page_t`` token slots each, plus B trailing rows holding the
# per-row last hidden state. Which pool pages a batch row owns is *not*
# part of the state: a page table ``[B, P]`` of page ids (P =
# ``pages_per_row``) is an i32 input uploaded per call, so the Rust
# allocator can hand pages out, share read-only prompt-prefix pages between
# rows, and free them at harvest without ever touching device memory.
#
# Page 0 is the reserved scratch page: table entries for unallocated slots
# point there, vacant rows write there, and nothing ever attends to it —
# the ``iota(P*bt) <= pidx`` mask excludes every unwritten position, and
# scratch contents stay finite, so masked columns contribute exactly 0.
#
# Physical row of (layer l, K half, page g, slot s) is
# ``(2l*N + g)*bt + s``; the V half adds N pages. Gathering a row's pages
# in table order reconstructs the v1 logical [P*bt, D] cache window, which
# is why ``paged_step`` is value-for-value the v1 ``decode_step`` (the
# parity suites ride on that).
# ---------------------------------------------------------------------------


def paged_state_rows(cfg: ModelConfig) -> int:
    """First dim of the paged decode state: L*2 pools of N pages x page_t
    slots each, plus B per-row hidden-state rows."""
    return cfg.n_layers * 2 * cfg.page_n * cfg.page_t + cfg.batch


def paged_step(tok, pidx, table, state, emb, pos, *bps, cfg: ModelConfig,
               backend: str):
    """One cached decode step over the paged state.

    tok/pidx: [B,1] i32 as in v1; table: [B,P] i32 page ids; state:
    [L*2*N*bt + B, D]. Writes each row's new K/V into slot ``pidx % bt``
    of page ``table[b, pidx // bt]`` (scatter-set — pages are
    exclusively owned or scratch, see the allocator contract), then
    gathers the row's pages in table order and attends the single query
    over positions ``t <= pidx`` exactly like v1. The B trailing rows
    get the new per-row hidden state.
    """
    bt, p, n, b = cfg.page_t, cfg.pages_per_row, cfg.page_n, cfg.batch
    kv_rows = cfg.n_layers * 2 * n * bt
    h = emb[tok] + pos[pidx]  # [B,1,D]
    page = jnp.take_along_axis(table, pidx // bt, axis=1)[:, 0]  # [B]
    slot = pidx[:, 0] % bt  # [B]
    mask = jax.lax.iota(jnp.int32, p * bt)[None, :] <= pidx  # [B, P*bt]
    in_page = jnp.arange(bt, dtype=jnp.int32)
    for l in range(cfg.n_layers):
        g1, wq, wk, wv, wo, g2, w1, w2 = bps[8 * l:8 * (l + 1)]
        x = _norm(h, g1, cfg, backend)
        q, k_new, v_new = x @ wq, x @ wk, x @ wv  # [B,1,D]
        k_base, v_base = 2 * l * n, (2 * l + 1) * n
        # write first, gather after: the current column is attendable
        state = state.at[(k_base + page) * bt + slot].set(k_new[:, 0, :])
        state = state.at[(v_base + page) * bt + slot].set(v_new[:, 0, :])
        k_idx = ((k_base + table) * bt)[:, :, None] + in_page  # [B,P,bt]
        v_idx = ((v_base + table) * bt)[:, :, None] + in_page
        kc = state[k_idx.reshape(b, p * bt)]  # [B, P*bt, D]
        vc = state[v_idx.reshape(b, p * bt)]
        h1 = h + _decode_attend(q, kc, vc, mask, cfg) @ wo
        y = _norm(h1, g2, cfg, backend)
        h = h1 + jax.nn.gelu(y @ w1) @ w2
    return jnp.concatenate([state[:kv_rows], h[:, 0, :]], axis=0)


def paged_scatter(state, table, *kvs, cfg: ModelConfig):
    """Seed the paged pools from the L per-layer ``prefill_kv`` outputs
    (batch prefill reuses the v1 prompt pipeline unchanged): position c of
    row b lands in slot ``c % bt`` of page ``table[b, c // bt]``. The h
    rows are left as-is — the first ``paged_step`` rewrites them before
    anything reads them."""
    assert len(kvs) == cfg.n_layers
    bt, n, b, t, d = cfg.page_t, cfg.page_n, cfg.batch, cfg.seq, cfg.d_model
    pos_page = jnp.arange(t, dtype=jnp.int32) // bt  # [T]
    pos_slot = jnp.arange(t, dtype=jnp.int32) % bt
    for l, kv in enumerate(kvs):
        for base, sl in ((2 * l * n, slice(0, t)),
                         ((2 * l + 1) * n, slice(t, 2 * t))):
            rows = (base + table[:, pos_page]) * bt + pos_slot[None, :]
            state = state.at[rows.reshape(-1)].set(
                kv[:, sl, :].reshape(b * t, d))
    return state


def paged_logits(state, gf, wh, *, cfg: ModelConfig, backend: str):
    """Next-token logits from the B trailing h rows: -> [B, 1, V]."""
    h = state[-cfg.batch:, :][:, None, :]
    x = _norm(h, gf, cfg, backend)
    return x @ wh


# ---------------------------------------------------------------------------
# Quantized-base segments (int8-chan, DESIGN.md §15)
#
# Every frozen weight matmul has a ``*_q8`` twin whose 2-D weights arrive as
# ``(q int8, s f32[out])`` pairs with dequant fused into the matmul
# (``kernels/quant.py`` on the pallas backend, the identical jnp expression
# otherwise) — no f32 weight tensor is ever materialized on device. The
# operand ABI mirrors the f32 one with each 2-D weight expanded in place to
# its (q, s) pair; 1-D norm gains stay f32. Per-block quantized param order:
#
#     (g1, wq_q, wq_s, wk_q, wk_s, wv_q, wv_s, wo_q, wo_s,
#      g2, w1_q, w1_s, w2_q, w2_s)
#
# Only segments whose weights can be frozen get a q8 twin: backward
# variants that produce weight gradients (``block_bwd_full``,
# ``head_fwd_bwd``, ``embed_bwd``) have none by construction — a trainable
# tensor is always f32 (the Rust engine enforces the selection per key).
# ---------------------------------------------------------------------------


Q8_BLOCK_PARAMS = 14  # the 8-tuple with each of the six 2-D weights split


def embed_fwd_q8(tokens, emb_q, emb_s, pos_q, pos_s, *, cfg: ModelConfig):
    """Quantized embedding: gather-dequant, no matmul to fuse into."""
    return _q8_embed(tokens, emb_q, emb_s) + (
        pos_q.astype(jnp.float32) * pos_s)[None, :, :]


def block_core_q8(h, qp, cfg: ModelConfig, backend: str, lora=None):
    """``block_core`` over a quantized 14-tuple; LoRA adapters stay f32."""
    (g1, wq_q, wq_s, wk_q, wk_s, wv_q, wv_s, wo_q, wo_s,
     g2, w1_q, w1_s, w2_q, w2_s) = qp
    scale = cfg.lora_alpha / cfg.lora_rank if lora is not None else 0.0
    la = lora if lora is not None else [None] * 12

    def lin(x, q, s, a, b):
        y = _q8_lin(x, q, s, cfg, backend)
        if lora is not None:
            y = y + (x @ a) @ b * scale
        return y

    x = _norm(h, g1, cfg, backend)
    q = _split_heads(lin(x, wq_q, wq_s, la[0], la[1]), cfg)
    k = _split_heads(lin(x, wk_q, wk_s, la[2], la[3]), cfg)
    v = _split_heads(lin(x, wv_q, wv_s, la[4], la[5]), cfg)
    o = _merge_heads(_attention(q, k, v, cfg, backend), cfg)
    h1 = h + lin(o, wo_q, wo_s, la[6], la[7])
    y = _norm(h1, g2, cfg, backend)
    ff = lin(jax.nn.gelu(lin(y, w1_q, w1_s, la[8], la[9])),
             w2_q, w2_s, la[10], la[11])
    return h1 + ff


def block_fwd_q8(h, *qp, cfg: ModelConfig, backend: str):
    return block_core_q8(h, qp, cfg, backend)


def block_bwd_x_q8(dh_out, h_in, *qp, cfg: ModelConfig, backend: str):
    """Frozen quantized block backward: input gradient only -> dh_in."""
    _, vjp = jax.vjp(lambda h: block_core_q8(h, qp, cfg, backend), h_in)
    (dh_in,) = vjp(dh_out)
    return dh_in


def block_fwd_lora_q8(h, *ps, cfg: ModelConfig, backend: str):
    qp, lora = ps[:Q8_BLOCK_PARAMS], ps[Q8_BLOCK_PARAMS:]
    return block_core_q8(h, qp, cfg, backend, lora=lora)


def block_bwd_lora_q8(dh_out, h_in, *ps, cfg: ModelConfig, backend: str):
    """LoRA backward over a quantized base: -> (dh_in, dA/dB x6 pairs)."""
    qp, lora = ps[:Q8_BLOCK_PARAMS], ps[Q8_BLOCK_PARAMS:]
    _, vjp = jax.vjp(
        lambda h, *l: block_core_q8(h, qp, cfg, backend, lora=l),
        h_in, *lora)
    return vjp(dh_out)  # (dh_in, *dlora)


def _head_loss_q8(h, gf, wh_q, wh_s, targets, cfg: ModelConfig, backend: str):
    x = _norm(h, gf, cfg, backend)
    logits = _q8_lin(x.reshape(-1, cfg.d_model), wh_q, wh_s, cfg, backend)
    return _xent(logits, targets.reshape(-1), cfg, backend)


def head_fwd_bwd_x_q8(h, gf, wh_q, wh_s, targets, *, cfg: ModelConfig,
                      backend: str):
    """Frozen quantized head: -> (loss, dh)."""
    loss, vjp = jax.vjp(
        lambda h: _head_loss_q8(h, gf, wh_q, wh_s, targets, cfg, backend), h)
    (dh,) = vjp(jnp.float32(1.0))
    return loss, dh


def head_loss_q8(h, gf, wh_q, wh_s, targets, *, cfg: ModelConfig,
                 backend: str):
    return _head_loss_q8(h, gf, wh_q, wh_s, targets, cfg, backend)


def head_logits_q8(h, gf, wh_q, wh_s, *, cfg: ModelConfig, backend: str):
    x = _norm(h, gf, cfg, backend)
    return _q8_lin(x, wh_q, wh_s, cfg, backend)


def prefill_kv_q8(h, g1, wk_q, wk_s, wv_q, wv_s, *, cfg: ModelConfig,
                  backend: str):
    """Quantized per-layer prompt K/V: same packing as ``prefill_kv``."""
    x = _norm(h, g1, cfg, backend)
    return jnp.concatenate([_q8_lin(x, wk_q, wk_s, cfg, backend),
                            _q8_lin(x, wv_q, wv_s, cfg, backend)], axis=1)


def decode_step_q8(tok, pidx, state, emb_q, emb_s, pos_q, pos_s, *qbps,
                   cfg: ModelConfig, backend: str):
    """Quantized ``decode_step``: same state layout, (q, s) weight pairs."""
    t_max = cfg.seq
    h = _q8_embed(tok, emb_q, emb_s) + _q8_embed(pidx, pos_q, pos_s)
    onehot = jax.nn.one_hot(pidx[:, 0], t_max, dtype=jnp.float32)  # [B,T]
    mask = jax.lax.iota(jnp.int32, t_max)[None, :] <= pidx  # [B,T]
    rows = []
    for l in range(cfg.n_layers):
        (g1, wq_q, wq_s, wk_q, wk_s, wv_q, wv_s, wo_q, wo_s,
         g2, w1_q, w1_s, w2_q, w2_s) = \
            qbps[Q8_BLOCK_PARAMS * l:Q8_BLOCK_PARAMS * (l + 1)]
        kc = state[:, l * 2 * t_max:l * 2 * t_max + t_max, :]
        vc = state[:, l * 2 * t_max + t_max:(l + 1) * 2 * t_max, :]
        x = _norm(h, g1, cfg, backend)
        q = _q8_lin(x, wq_q, wq_s, cfg, backend)
        k_new = _q8_lin(x, wk_q, wk_s, cfg, backend)
        v_new = _q8_lin(x, wv_q, wv_s, cfg, backend)
        keep = 1.0 - onehot[:, :, None]
        kc = kc * keep + k_new * onehot[:, :, None]
        vc = vc * keep + v_new * onehot[:, :, None]
        o = _decode_attend(q, kc, vc, mask, cfg)
        h1 = h + _q8_lin(o, wo_q, wo_s, cfg, backend)
        y = _norm(h1, g2, cfg, backend)
        h = h1 + _q8_lin(jax.nn.gelu(_q8_lin(y, w1_q, w1_s, cfg, backend)),
                         w2_q, w2_s, cfg, backend)
        rows.extend((kc, vc))
    return jnp.concatenate([*rows, h], axis=1)


def decode_logits_q8(state, gf, wh_q, wh_s, *, cfg: ModelConfig,
                     backend: str):
    h = state[:, -1:, :]
    x = _norm(h, gf, cfg, backend)
    return _q8_lin(x, wh_q, wh_s, cfg, backend)


def paged_step_q8(tok, pidx, table, state, emb_q, emb_s, pos_q, pos_s,
                  *qbps, cfg: ModelConfig, backend: str):
    """Quantized ``paged_step``: same paged geometry, (q, s) weight pairs."""
    bt, p, n, b = cfg.page_t, cfg.pages_per_row, cfg.page_n, cfg.batch
    kv_rows = cfg.n_layers * 2 * n * bt
    h = _q8_embed(tok, emb_q, emb_s) + _q8_embed(pidx, pos_q, pos_s)
    page = jnp.take_along_axis(table, pidx // bt, axis=1)[:, 0]  # [B]
    slot = pidx[:, 0] % bt  # [B]
    mask = jax.lax.iota(jnp.int32, p * bt)[None, :] <= pidx  # [B, P*bt]
    in_page = jnp.arange(bt, dtype=jnp.int32)
    for l in range(cfg.n_layers):
        (g1, wq_q, wq_s, wk_q, wk_s, wv_q, wv_s, wo_q, wo_s,
         g2, w1_q, w1_s, w2_q, w2_s) = \
            qbps[Q8_BLOCK_PARAMS * l:Q8_BLOCK_PARAMS * (l + 1)]
        x = _norm(h, g1, cfg, backend)
        q = _q8_lin(x, wq_q, wq_s, cfg, backend)
        k_new = _q8_lin(x, wk_q, wk_s, cfg, backend)
        v_new = _q8_lin(x, wv_q, wv_s, cfg, backend)
        k_base, v_base = 2 * l * n, (2 * l + 1) * n
        state = state.at[(k_base + page) * bt + slot].set(k_new[:, 0, :])
        state = state.at[(v_base + page) * bt + slot].set(v_new[:, 0, :])
        k_idx = ((k_base + table) * bt)[:, :, None] + in_page  # [B,P,bt]
        v_idx = ((v_base + table) * bt)[:, :, None] + in_page
        kc = state[k_idx.reshape(b, p * bt)]  # [B, P*bt, D]
        vc = state[v_idx.reshape(b, p * bt)]
        o = _decode_attend(q, kc, vc, mask, cfg)
        h1 = h + _q8_lin(o, wo_q, wo_s, cfg, backend)
        y = _norm(h1, g2, cfg, backend)
        h = h1 + _q8_lin(jax.nn.gelu(_q8_lin(y, w1_q, w1_s, cfg, backend)),
                         w2_q, w2_s, cfg, backend)
    return jnp.concatenate([state[:kv_rows], h[:, 0, :]], axis=0)


def paged_logits_q8(state, gf, wh_q, wh_s, *, cfg: ModelConfig,
                    backend: str):
    h = state[-cfg.batch:, :][:, None, :]
    x = _norm(h, gf, cfg, backend)
    return _q8_lin(x, wh_q, wh_s, cfg, backend)


# ---------------------------------------------------------------------------
# Whole-model reference (tests + the pytest oracle for segment composition)
# ---------------------------------------------------------------------------

def model_loss(tokens, targets, embed_params, blocks_params, head_params,
               cfg: ModelConfig, backend: str = "jnp", lora=None):
    """Full forward loss composed from the segments (oracle for tests)."""
    h = embed_fwd(tokens, *embed_params, cfg=cfg)
    for i, bp in enumerate(blocks_params):
        if lora is not None:
            h = block_fwd_lora(h, *bp, *lora[i], cfg=cfg, backend=backend)
        else:
            h = block_fwd(h, *bp, cfg=cfg, backend=backend)
    return head_loss(h, *head_params, targets, cfg=cfg, backend=backend)
