"""AOT exporter: lower every Layer-2 segment to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized ``HloModuleProto`` —
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per config this writes::

    artifacts/<config>/<segment>.<backend>.hlo.txt   # backend in {pallas,jnp}
    artifacts/<config>/manifest.json                 # shapes the Rust loader
                                                     # validates against

Multi-output segments lower with ``return_tuple=True`` (one tuple the Rust
side unwraps on the host). Single-output segments lower with a *bare* root
(``return_tuple=False``) and are flagged ``tuple_root: false`` in the
manifest: their PJRT output buffer IS the value, so the Rust engine can
chain it straight into the next segment as a device-resident operand
(``rust/src/runtime/client.rs::run_chained``) — the residual stream never
round-trips through the host. Python runs only here — never on the
training path.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS, ModelConfig
from .kernels.adamw import HYPER_LEN, adamw_update


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def segment_registry(cfg: ModelConfig, backend: str):
    """name -> (fn, [operand ShapeDtypeStructs]). Operand order is the ABI
    the Rust engine follows (rust/src/runtime/artifacts.rs)."""
    b, t, d, v = cfg.batch, cfg.seq, cfg.d_model, cfg.vocab
    h3 = _spec((b, t, d))
    tok = _spec((b, t), jnp.int32)
    bp = [_spec(s) for _, s in cfg.block_param_shapes()]
    lp = [_spec(s) for _, s in cfg.lora_param_shapes()]
    gf, wh = [_spec(s) for _, s in cfg.head_param_shapes()]
    emb, pos = [_spec(s) for _, s in cfg.embed_param_shapes()]
    kw = dict(cfg=cfg, backend=backend)
    n_opt = cfg.d_model * cfg.d_ff  # largest single block tensor
    flat = _spec((n_opt,))
    # decode ABI (DESIGN.md §9): [B,1] token/position columns, the per-layer
    # packed K/V block and the whole-model packed decode state
    tok1 = _spec((b, 1), jnp.int32)
    kv = _spec((b, 2 * t, d))
    state = _spec((b, model.decode_state_rows(cfg), d))
    # decode ABI v2 (DESIGN.md §12): paged pools + per-row page table
    ptab = _spec((b, cfg.pages_per_row), jnp.int32)
    pstate = _spec((model.paged_state_rows(cfg), d))
    # quantized-base ABI (DESIGN.md §15): every 2-D weight expands in place
    # to its (q int8, s f32[out]) pair; 1-D norm gains stay f32
    def _qpair(shape):
        return [_spec(shape, jnp.int8), _spec((shape[-1],))]
    qbp = []
    for _, s in cfg.block_param_shapes():
        qbp.extend(_qpair(s) if len(s) == 2 else [_spec(s)])
    emb_q, pos_q = [_qpair(s) for _, s in cfg.embed_param_shapes()]
    wh_q = _qpair(cfg.head_param_shapes()[1][1])

    return {
        "embed_fwd": (functools.partial(model.embed_fwd, cfg=cfg),
                      [tok, emb, pos]),
        "embed_bwd": (functools.partial(model.embed_bwd, cfg=cfg),
                      [h3, tok]),
        "block_fwd": (functools.partial(model.block_fwd, **kw),
                      [h3, *bp]),
        "block_bwd_full": (functools.partial(model.block_bwd_full, **kw),
                           [h3, h3, *bp]),
        "block_bwd_x": (functools.partial(model.block_bwd_x, **kw),
                        [h3, h3, *bp]),
        "block_fwd_lora": (functools.partial(model.block_fwd_lora, **kw),
                           [h3, *bp, *lp]),
        "block_bwd_lora": (functools.partial(model.block_bwd_lora, **kw),
                           [h3, h3, *bp, *lp]),
        "head_fwd_bwd": (functools.partial(model.head_fwd_bwd, **kw),
                         [h3, gf, wh, tok]),
        "head_fwd_bwd_x": (functools.partial(model.head_fwd_bwd_x, **kw),
                           [h3, gf, wh, tok]),
        "head_loss": (functools.partial(model.head_loss, **kw),
                      [h3, gf, wh, tok]),
        "head_logits": (functools.partial(model.head_logits, **kw),
                        [h3, gf, wh]),
        "adamw_update": (
            lambda p, g, m, vv, hy: adamw_update(p, g, m, vv, hy,
                                                 interpret=True),
            [flat, flat, flat, flat, _spec((HYPER_LEN,))]),
        # serving: batched KV-cached decode (ABI v1, DESIGN.md §9). All
        # four are single-output -> bare-rooted -> device-chainable, which
        # is what keeps the cache state resident across decode steps.
        "prefill_kv": (functools.partial(model.prefill_kv, **kw),
                       [h3, bp[0], bp[2], bp[3]]),  # h, g1, wk, wv
        "pack_state": (functools.partial(model.pack_state, cfg=cfg),
                       [kv] * cfg.n_layers),
        "decode_step": (functools.partial(model.decode_step, **kw),
                        [tok1, tok1, state, emb, pos, *(bp * cfg.n_layers)]),
        "decode_logits": (functools.partial(model.decode_logits, **kw),
                          [state, gf, wh]),
        # serving: paged K/V cache (ABI v2, DESIGN.md §12). Single-output
        # -> bare-rooted -> the paged state chains device-resident exactly
        # like the v1 packed state; the page table is a per-call i32 input.
        "paged_scatter": (functools.partial(model.paged_scatter, cfg=cfg),
                          [pstate, ptab, *([kv] * cfg.n_layers)]),
        "paged_step": (functools.partial(model.paged_step, **kw),
                       [tok1, tok1, ptab, pstate, emb, pos,
                        *(bp * cfg.n_layers)]),
        "paged_logits": (functools.partial(model.paged_logits, **kw),
                         [pstate, gf, wh]),
        # quantized-base twins (DESIGN.md §15): frozen weights arrive as
        # (int8, per-output-channel f32 scale) pairs, dequant fused into the
        # matmul. Only freezable segments have twins — backward variants
        # that emit weight gradients stay f32-only by construction.
        "embed_fwd_q8": (functools.partial(model.embed_fwd_q8, cfg=cfg),
                         [tok, *emb_q, *pos_q]),
        "block_fwd_q8": (functools.partial(model.block_fwd_q8, **kw),
                         [h3, *qbp]),
        "block_bwd_x_q8": (functools.partial(model.block_bwd_x_q8, **kw),
                           [h3, h3, *qbp]),
        "block_fwd_lora_q8": (
            functools.partial(model.block_fwd_lora_q8, **kw),
            [h3, *qbp, *lp]),
        "block_bwd_lora_q8": (
            functools.partial(model.block_bwd_lora_q8, **kw),
            [h3, h3, *qbp, *lp]),
        "head_fwd_bwd_x_q8": (
            functools.partial(model.head_fwd_bwd_x_q8, **kw),
            [h3, gf, *wh_q, tok]),
        "head_loss_q8": (functools.partial(model.head_loss_q8, **kw),
                         [h3, gf, *wh_q, tok]),
        "head_logits_q8": (functools.partial(model.head_logits_q8, **kw),
                           [h3, gf, *wh_q]),
        "prefill_kv_q8": (functools.partial(model.prefill_kv_q8, **kw),
                          # h, g1, wk_q, wk_s, wv_q, wv_s
                          [h3, qbp[0], qbp[3], qbp[4], qbp[5], qbp[6]]),
        "decode_step_q8": (functools.partial(model.decode_step_q8, **kw),
                           [tok1, tok1, state, *emb_q, *pos_q,
                            *(qbp * cfg.n_layers)]),
        "decode_logits_q8": (
            functools.partial(model.decode_logits_q8, **kw),
            [state, gf, *wh_q]),
        "paged_step_q8": (functools.partial(model.paged_step_q8, **kw),
                          [tok1, tok1, ptab, pstate, *emb_q, *pos_q,
                           *(qbp * cfg.n_layers)]),
        "paged_logits_q8": (
            functools.partial(model.paged_logits_q8, **kw),
            [pstate, gf, *wh_q]),
    }


def _sig(specs):
    return [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs]


def export_config(cfg: ModelConfig, out_root: str, backends, force=False,
                  segments=None) -> dict:
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    # Merge with an existing manifest so partial re-exports (one backend or
    # a segment subset) don't drop previously exported entries.
    prev_segments = {}
    mpath = os.path.join(out_dir, "manifest.json")
    if os.path.exists(mpath):
        try:
            with open(mpath) as f:
                prev_segments = json.load(f).get("segments", {})
        except (json.JSONDecodeError, OSError):
            prev_segments = {}
    manifest = {
        "config": {
            "name": cfg.name, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "vocab": cfg.vocab, "seq": cfg.seq, "batch": cfg.batch,
            "mlp_ratio": cfg.mlp_ratio, "lora_rank": cfg.lora_rank,
            "lora_alpha": cfg.lora_alpha, "n_params": cfg.n_params(),
        },
        "block_params": [list(s) for _, s in cfg.block_param_shapes()],
        "block_param_names": [n for n, _ in cfg.block_param_shapes()],
        "lora_params": [list(s) for _, s in cfg.lora_param_shapes()],
        "lora_param_names": [n for n, _ in cfg.lora_param_shapes()],
        "segments": prev_segments,
    }
    for backend in backends:
        reg = segment_registry(cfg, backend)
        for name, (fn, specs) in reg.items():
            if segments and name not in segments:
                continue
            if name == "adamw_update" and backend != "pallas":
                continue  # the fused kernel IS the pallas artifact
            fname = f"{name}.{backend}.hlo.txt"
            path = os.path.join(out_dir, fname)
            key = f"{name}.{backend}"
            outs = jax.tree_util.tree_leaves(jax.eval_shape(fn, *specs))
            # Single-output segments get a bare root so the engine can
            # keep the output on-device and chain it (tuple_root below is
            # the loader's contract for which unwrap path to use).
            tuple_root = len(outs) != 1
            if os.path.exists(path) and not force and key in prev_segments:
                # The manifest must describe the HLO that is actually on
                # disk: a skipped (pre-existing) file keeps whatever root
                # convention it was exported with — recorded in the
                # previous manifest, tuple-rooted for legacy exports. A
                # file with *no* surviving manifest entry (deleted or
                # corrupt manifest) is re-lowered instead of guessed at.
                tuple_root = bool(prev_segments[key].get("tuple_root", True))
                print(f"  [skip] {cfg.name}/{fname}")
            else:
                lowered = jax.jit(fn).lower(*specs)
                text = to_hlo_text(lowered, return_tuple=tuple_root)
                with open(path, "w") as f:
                    f.write(text)
                print(f"  [ok]   {cfg.name}/{fname} "
                      f"({len(text) // 1024} KiB)")
            manifest["segments"][key] = {
                "file": fname,
                "operands": _sig(specs),
                "outputs": _sig(outs),
                "tuple_root": tuple_root,
            }
    # Decode-ABI version (DESIGN.md §9/§12): claimed only when every decode
    # segment is really in the manifest for some backend, so partial
    # exports can't advertise an ABI they don't carry. Loaders treat a
    # missing/0 field as "no decode" — legacy artifact dirs keep loading.
    # v2 (paged) is a superset of v1: the batch-prefill pipeline and the
    # parity baseline both still run the v1 segments, so abi 2 is only
    # stamped when both sets are complete for one backend.
    decode_names = ("prefill_kv", "pack_state", "decode_step", "decode_logits")
    paged_names = decode_names + ("paged_step", "paged_logits",
                                  "paged_scatter")
    has_v1 = any(all(f"{n}.{be}" in manifest["segments"] for n in decode_names)
                 for be in ("pallas", "jnp"))
    has_v2 = any(all(f"{n}.{be}" in manifest["segments"] for n in paged_names)
                 for be in ("pallas", "jnp"))
    manifest["decode_abi"] = 2 if has_v2 else (1 if has_v1 else 0)
    if has_v2:
        # paged geometry the Rust allocator/loader validates against
        manifest["paged"] = {
            "page_t": cfg.page_t,
            "pages_per_row": cfg.pages_per_row,
            "page_n": cfg.page_n,
            "state_rows": model.paged_state_rows(cfg),
        }
    # Quantized-base mode (DESIGN.md §15): stamped only when the full q8
    # core set is present for some backend, same completeness rule as the
    # decode ABI — a partial export can't advertise quant support. Loaders
    # treat a missing block as "f32 only"; legacy dirs keep loading.
    quant_core = ("embed_fwd_q8", "block_fwd_q8", "block_bwd_x_q8",
                  "block_fwd_lora_q8", "block_bwd_lora_q8",
                  "head_fwd_bwd_x_q8", "head_loss_q8", "head_logits_q8")
    quant_decode = ("prefill_kv_q8", "decode_step_q8", "decode_logits_q8")
    quant_paged = ("paged_step_q8", "paged_logits_q8")
    has_q = any(
        all(f"{n}.{be}" in manifest["segments"] for n in quant_core)
        for be in ("pallas", "jnp"))
    if has_q:
        stamped = [n for n in (*quant_core, *quant_decode, *quant_paged)
                   if any(f"{n}.{be}" in manifest["segments"]
                          for be in ("pallas", "jnp"))]
        manifest["quant"] = {"mode": "int8-chan", "segments": stamped}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small",
                    help="comma list from: " + ",".join(CONFIGS))
    ap.add_argument("--backends", default="pallas,jnp")
    ap.add_argument("--segments", default="",
                    help="optional comma list to restrict segments")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    segments = set(s for s in args.segments.split(",") if s) or None
    for cname in args.configs.split(","):
        cfg = CONFIGS[cname]
        print(f"[config {cname}] {cfg.n_params()/1e6:.1f}M params")
        export_config(cfg, args.out, args.backends.split(","), args.force,
                      segments)
    print("AOT export complete.")


if __name__ == "__main__":
    main()
