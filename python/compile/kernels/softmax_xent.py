"""Fused masked softmax cross-entropy (the LM-head loss) as a Pallas kernel.

For each tile of rows the kernel computes, in one VMEM residency of the
[block_n, V] logit tile: the row max, the log-sum-exp, the per-row loss
(masked by ``target >= 0``) and the gradient w.r.t. the logits
``(softmax - onehot) * valid``. Host-side we reduce per-row losses to the
mean and scale dlogits by ``1/n_valid`` — the same contract as
``ref.softmax_xent``.

This fusion is the memory win the LM head needs: an unfused implementation
materializes probs + onehot + several [N, V] temporaries; here a logit tile
is read once and its gradient written once.

Targets use ``-1`` as ignore_index (prompt tokens in SFT are masked).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, want: int) -> int:
    b = min(want, n)
    while n % b != 0:
        b -= 1
    return b


def _kernel(logits_ref, targets_ref, loss_ref, dlogits_ref):
    logits = logits_ref[...]          # [block_n, V]
    targets = targets_ref[...]        # [block_n]
    bn, v = logits.shape
    valid = targets >= 0
    safe_t = jnp.where(valid, targets, 0)

    mx = jnp.max(logits, axis=-1)
    ex = jnp.exp(logits - mx[:, None])
    denom = jnp.sum(ex, axis=-1)
    lse = mx + jnp.log(denom)

    cols = jax.lax.iota(jnp.int32, v)
    onehot = (cols[None, :] == safe_t[:, None]).astype(jnp.float32)
    ll = jnp.sum(logits * onehot, axis=-1)

    validf = valid.astype(jnp.float32)
    loss_ref[...] = (lse - ll) * validf
    probs = ex / denom[:, None]
    dlogits_ref[...] = (probs - onehot) * validf[:, None]


def softmax_xent(logits, targets, *, block_n=8, interpret=True):
    """Masked mean CE. logits: [N, V] f32, targets: [N] i32 (-1 ignored).

    Returns (loss_scalar, dlogits) — gradients of the mean loss.
    """
    n, v = logits.shape
    block_n = _pick_block(n, block_n)
    grid = (n // block_n,)
    per_row, dlogits = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, v), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, v), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n, v), jnp.float32),
        ],
        interpret=interpret,
    )(logits, targets)
    n_valid = jnp.maximum(jnp.sum((targets >= 0).astype(jnp.float32)), 1.0)
    return jnp.sum(per_row) / n_valid, dlogits / n_valid


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def xent_loss(logits, targets, block_n=8, interpret=True):
    """Scalar masked mean CE, differentiable w.r.t. logits via the fused
    kernel's dlogits (so ``jax.vjp`` over the L2 head uses the kernel)."""
    loss, _ = softmax_xent(logits, targets, block_n=block_n,
                           interpret=interpret)
    return loss


def _xl_fwd(logits, targets, block_n, interpret):
    loss, dlogits = softmax_xent(logits, targets, block_n=block_n,
                                 interpret=interpret)
    return loss, dlogits


def _xl_bwd(block_n, interpret, dlogits, gbar):
    return dlogits * gbar, None


xent_loss.defvjp(_xl_fwd, _xl_bwd)


def vmem_bytes(v: int, block_n: int, bytes_per_el: int = 4) -> int:
    """Peak VMEM per grid step: logit tile, grad tile, ex tile + row vectors."""
    return (3 * block_n * v + 6 * block_n) * bytes_per_el
