"""Fused AdamW update as a Pallas kernel.

One elementwise pass over a flat parameter vector: reads (p, g, m, v) tiles
from HBM into VMEM, applies the decoupled-weight-decay AdamW step and writes
(p', m', v') back — 4 reads + 3 writes per element, the memory-bound optimum
(an unfused jnp AdamW materializes ~6 intermediates).

The production optimizer of this repo lives in Rust (``rust/src/opt``); this
kernel is exported as the ``adamw_update`` artifact for the L1-vs-L3 ablation
bench (EXPERIMENTS.md §Perf) and as the reference fused formulation.

Hyperparameters arrive as a length-8 float32 operand
``[lr, beta1, beta2, eps, weight_decay, bc1, bc2, _pad]`` where
``bc{1,2} = 1 - beta^t`` are the bias corrections precomputed by the caller
(the step counter lives in the Rust coordinator, not the graph).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

HYPER_LEN = 8


def _pick_block(n: int, want: int) -> int:
    b = min(want, n)
    while n % b != 0:
        b -= 1
    return b


def _kernel(p_ref, g_ref, m_ref, v_ref, hyper_ref, p_out, m_out, v_out):
    p = p_ref[...]
    g = g_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    h = hyper_ref[...]
    lr, b1, b2, eps, wd, bc1, bc2 = h[0], h[1], h[2], h[3], h[4], h[5], h[6]
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    mhat = m2 / bc1
    vhat = v2 / bc2
    p_out[...] = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    m_out[...] = m2
    v_out[...] = v2


def adamw_update(p, g, m, v, hyper, *, block=4096, interpret=True):
    """Fused AdamW. All of p,g,m,v are flat [n] float32; hyper is [8].

    Returns (p', m', v').
    """
    (n,) = p.shape
    block = _pick_block(n, block)
    grid = (n // block,)
    vec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[vec, vec, vec, vec,
                  pl.BlockSpec((HYPER_LEN,), lambda i: (0,))],
        out_specs=[vec, vec, vec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=interpret,
    )(p, g, m, v, hyper)


def pack_hyper(lr, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0,
               step=1):
    """Builds the [8] hyper operand; ``step`` is 1-based."""
    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    return jnp.array([lr, beta1, beta2, eps, weight_decay, bc1, bc2, 0.0],
                     dtype=jnp.float32)


def vmem_bytes(block: int, bytes_per_el: int = 4) -> int:
    """Peak VMEM per grid step: 4 input tiles + 3 output tiles + hyper."""
    return (7 * block + HYPER_LEN) * bytes_per_el
