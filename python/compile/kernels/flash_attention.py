"""Flash attention as a Pallas kernel (TPU-shaped, run under interpret=True).

This is the Layer-1 hot-spot of the LISA reproduction: causal multi-head
attention with an online-softmax forward and a two-kernel backward
(dq kernel gridded over query tiles, dkv kernel gridded over key tiles),
wrapped in ``jax.custom_vjp`` so the Layer-2 block functions differentiate
through the hand-written kernels.

Hardware adaptation (paper targets CUDA, we target TPU — see
DESIGN.md §Hardware-Adaptation): the HBM↔VMEM schedule is expressed with
``BlockSpec`` — a query tile of shape [block_q, Dh] is staged into VMEM per
grid step while K/V for the whole sequence are resident (fine for the
sequence lengths this repo trains: T·Dh·4B ≤ 1 MB ≪ 16 MB VMEM), and the
inner loop walks K/V in [block_k, Dh] tiles with running (m, l, acc)
accumulators — the classic online softmax. Tile sizes default to MXU-friendly
multiples; ``vmem_estimate`` below is what DESIGN/EXPERIMENTS quote.

Everything is float32: the CPU PJRT plugin the Rust runtime uses executes
the interpret-mode lowering, which is float32-exact against ``ref.py``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30  # avoids -inf - -inf = nan in fully-masked tiles


def _pick_block(t: int, want: int) -> int:
    """Largest divisor of ``t`` that is <= want (tiles must divide T here)."""
    b = min(want, t)
    while t % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, sm_scale, block_k,
                causal, seq_len):
    # q_ref: [1, 1, block_q, d]; k_ref/v_ref: [1, 1, T, d]
    q = q_ref[0, 0]
    block_q, d = q.shape
    start_q = pl.program_id(2) * block_q
    q_ids = start_q + jax.lax.iota(jnp.int32, block_q)

    m = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((block_q,), dtype=jnp.float32)
    acc = jnp.zeros((block_q, d), dtype=jnp.float32)

    num_kb = seq_len // block_k

    def body(i, carry):
        m, l, acc = carry
        start_k = i * block_k
        k = k_ref[0, 0, pl.ds(start_k, block_k), :]
        v = v_ref[0, 0, pl.ds(start_k, block_k), :]
        s = jnp.dot(q, k.T) * sm_scale  # [block_q, block_k]
        if causal:
            k_ids = start_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_ids[:, None] >= k_ids[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v)
        return m_new, l_new, acc_new

    # Causal runs could bound the loop at the tile containing the last query
    # index, but fori_loop bounds must be trace-time constants under the
    # interpret path — we walk all tiles and let the mask zero the upper
    # triangle. The TPU cost model (triangular schedule) is quoted in §Perf.
    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m, l, acc))

    o_ref[0, 0] = acc / l[:, None]
    lse_ref[0, 0] = m + jnp.log(l)


# ---------------------------------------------------------------------------
# Backward kernels
# ---------------------------------------------------------------------------

def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *,
                   sm_scale, block_k, causal, seq_len):
    q = q_ref[0, 0]
    do = do_ref[0, 0]
    lse = lse_ref[0, 0]
    delta = delta_ref[0, 0]
    block_q, d = q.shape
    start_q = pl.program_id(2) * block_q
    q_ids = start_q + jax.lax.iota(jnp.int32, block_q)
    num_kb = seq_len // block_k

    def body(i, dq):
        start_k = i * block_k
        k = k_ref[0, 0, pl.ds(start_k, block_k), :]
        v = v_ref[0, 0, pl.ds(start_k, block_k), :]
        s = jnp.dot(q, k.T) * sm_scale
        if causal:
            k_ids = start_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_ids[:, None] >= k_ids[None, :]
            s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # [block_q, block_k]
        dp = jnp.dot(do, v.T)
        ds = p * (dp - delta[:, None]) * sm_scale
        return dq + jnp.dot(ds, k)

    dq = jax.lax.fori_loop(0, num_kb, body, jnp.zeros((block_q, d), jnp.float32))
    dq_ref[0, 0] = dq


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, *, sm_scale, block_q, causal, seq_len):
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    block_k, d = k.shape
    start_k = pl.program_id(2) * block_k
    k_ids = start_k + jax.lax.iota(jnp.int32, block_k)
    num_qb = seq_len // block_q

    def body(i, carry):
        dk, dv = carry
        start_q = i * block_q
        q = q_ref[0, 0, pl.ds(start_q, block_q), :]
        do = do_ref[0, 0, pl.ds(start_q, block_q), :]
        lse = lse_ref[0, 0, pl.ds(start_q, block_q)]
        delta = delta_ref[0, 0, pl.ds(start_q, block_q)]
        s = jnp.dot(q, k.T) * sm_scale  # [block_q, block_k]
        if causal:
            q_ids = start_q + jax.lax.iota(jnp.int32, block_q)
            mask = q_ids[:, None] >= k_ids[None, :]
            s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dv_new = dv + jnp.dot(p.T, do)
        dp = jnp.dot(do, v.T)
        ds = p * (dp - delta[:, None]) * sm_scale
        dk_new = dk + jnp.dot(ds.T, q)
        return dk_new, dv_new

    zero = jnp.zeros((block_k, d), jnp.float32)
    dk, dv = jax.lax.fori_loop(0, num_qb, body, (zero, zero))
    dk_ref[0, 0] = dk
    dv_ref[0, 0] = dv


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------

def _fwd(q, k, v, *, causal, sm_scale, block_q, block_k, interpret):
    b, h, t, d = q.shape
    block_q = _pick_block(t, block_q)
    block_k = _pick_block(t, block_k)
    grid = (b, h, t // block_q)
    kern = functools.partial(_fwd_kernel, sm_scale=sm_scale, block_k=block_k,
                             causal=causal, seq_len=t)
    o, lse = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, t, d), lambda b_, h_, i: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, t, d), lambda b_, h_, i: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h_, i: (b_, h_, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, t), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse


def _bwd(q, k, v, o, lse, do, *, causal, sm_scale, block_q, block_k,
         interpret):
    b, h, t, d = q.shape
    block_q = _pick_block(t, block_q)
    block_k = _pick_block(t, block_k)
    delta = jnp.sum(do * o, axis=-1)  # [b, h, t]

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, sm_scale=sm_scale, block_k=block_k,
                          causal=causal, seq_len=t),
        grid=(b, h, t // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, t, d), lambda b_, h_, i: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, t, d), lambda b_, h_, i: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h_, i: (b_, h_, i)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h_, i: (b_, h_, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, i: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, d), jnp.float32),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, sm_scale=sm_scale, block_q=block_q,
                          causal=causal, seq_len=t),
        grid=(b, h, t // block_k),
        in_specs=[
            pl.BlockSpec((1, 1, t, d), lambda b_, h_, i: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, t, d), lambda b_, h_, i: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, t), lambda b_, h_, i: (b_, h_, 0)),
            pl.BlockSpec((1, 1, t), lambda b_, h_, i: (b_, h_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, i: (b_, h_, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, t, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Public entry point: custom_vjp so jax.vjp over the L2 block uses our bwd
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, sm_scale=None, block_q=128,
                    block_k=128, interpret=True):
    """Causal flash attention. q,k,v: [B,H,T,Dh] float32 -> [B,H,T,Dh]."""
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(q.shape[-1])
    o, _ = _fwd(q, k, v, causal=causal, sm_scale=sm_scale, block_q=block_q,
                block_k=block_k, interpret=interpret)
    return o


def _vjp_fwd(q, k, v, causal, sm_scale, block_q, block_k, interpret):
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(q.shape[-1])
    o, lse = _fwd(q, k, v, causal=causal, sm_scale=sm_scale, block_q=block_q,
                  block_k=block_k, interpret=interpret)
    return o, (q, k, v, o, lse)


def _vjp_bwd(causal, sm_scale, block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(q.shape[-1])
    dq, dk, dv = _bwd(q, k, v, o, lse, do, causal=causal, sm_scale=sm_scale,
                      block_q=block_q, block_k=block_k, interpret=interpret)
    return dq, dk, dv


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)


# ---------------------------------------------------------------------------
# TPU cost / VMEM model (used by EXPERIMENTS.md §Perf — interpret-mode
# wallclock is NOT a TPU proxy, so we reason about structure instead)
# ---------------------------------------------------------------------------

def vmem_bytes(t: int, d: int, block_q: int, block_k: int,
               bytes_per_el: int = 4) -> int:
    """Peak VMEM bytes for one grid step of the forward kernel.

    q tile + resident K + resident V + o tile + (m, l, acc) accumulators.
    """
    q_tile = block_q * d
    kv = 2 * t * d
    o_tile = block_q * d
    acc = block_q * d + 2 * block_q
    s_tile = block_q * block_k  # score tile materialized per inner step
    return (q_tile + kv + o_tile + acc + s_tile) * bytes_per_el


def mxu_utilization(t: int, d: int, block_q: int, block_k: int) -> float:
    """Fraction of MXU-issue slots doing useful work: tiles aligned to 128
    give 1.0; ragged tiles pay the pad. Causal masking halves useful work
    in off-diagonal handling; we report the dense-tile bound."""
    def eff(n: int) -> float:
        pad = (-n) % 128
        return n / (n + pad)
    return eff(block_q) * eff(block_k) * eff(d)
