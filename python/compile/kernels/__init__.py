"""Layer-1 Pallas kernels for the LISA reproduction.

All kernels run under ``interpret=True`` (the CPU PJRT plugin cannot execute
Mosaic custom-calls) and are float32-exact against the oracles in ``ref.py``.
"""

from . import ref  # noqa: F401
from .adamw import adamw_update, pack_hyper  # noqa: F401
from .flash_attention import flash_attention  # noqa: F401
from .quant import q8_matmul, quantize_per_channel  # noqa: F401
from .rmsnorm import rmsnorm  # noqa: F401
from .softmax_xent import softmax_xent  # noqa: F401
