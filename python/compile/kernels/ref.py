"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal of Layer 1: each kernel in
``flash_attention.py`` / ``rmsnorm.py`` / ``adamw.py`` / ``softmax_xent.py``
is checked against the function of the same name here by
``python/tests/test_kernels.py`` over a sweep of shapes, dtypes and tilings.

Everything here is written for clarity, not speed — no tiling, no online
softmax, no fused updates. Numerics are float32 throughout (the CPU PJRT
path the Rust runtime uses is float32).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True, sm_scale: float | None = None):
    """Plain softmax attention. q,k,v: [B, H, T, Dh] -> [B, H, T, Dh]."""
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * sm_scale
    if causal:
        t = q.shape[2]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        s = jnp.where(mask[None, None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def attention_lse(q, k, v, *, causal: bool = True, sm_scale: float | None = None):
    """Returns (o, lse) where lse[b,h,t] = logsumexp of the scaled scores.

    Matches the auxiliary output the flash kernel stashes for its backward.
    """
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * sm_scale
    if causal:
        t = q.shape[2]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        s = jnp.where(mask[None, None, :, :], s, -jnp.inf)
    lse = jax.scipy.special.logsumexp(s, axis=-1)
    p = jnp.exp(s - lse[..., None])
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v)
    return o, lse


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm(x, g, *, eps: float = 1e-6):
    """RMSNorm over the last axis. x: [..., D], g: [D]."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


# ---------------------------------------------------------------------------
# AdamW (decoupled weight decay, Loshchilov & Hutter 2017)
# ---------------------------------------------------------------------------

def adamw(p, g, m, v, *, lr, beta1=0.9, beta2=0.999, eps=1e-8,
          weight_decay=0.0, step=1):
    """One AdamW update. Returns (p', m', v'). ``step`` is 1-based."""
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * jnp.square(g)
    mhat = m2 / (1.0 - beta1 ** step)
    vhat = v2 / (1.0 - beta2 ** step)
    p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
    return p2, m2, v2


# ---------------------------------------------------------------------------
# Masked softmax cross-entropy (the LM-head loss)
# ---------------------------------------------------------------------------

def softmax_xent(logits, targets):
    """Mean CE over positions with target >= 0; returns (loss, dlogits).

    logits: [N, V] float32, targets: [N] int32 with -1 = ignore.
    dlogits is the gradient of the mean loss w.r.t. logits.
    """
    valid = targets >= 0
    safe_t = jnp.where(valid, targets, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe_t[:, None], axis=-1)[:, 0]
    per_row = (lse - ll) * valid.astype(logits.dtype)
    denom = jnp.maximum(valid.sum().astype(logits.dtype), 1.0)
    loss = per_row.sum() / denom
    probs = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(safe_t, logits.shape[-1], dtype=logits.dtype)
    dlogits = (probs - onehot) * valid[:, None].astype(logits.dtype) / denom
    return loss, dlogits
