"""Fused RMSNorm as a Pallas kernel with a hand-written backward.

Rows of the input are tiled into VMEM ([block_n, D] per grid step); the
forward computes ``y = x * rsqrt(mean(x^2)+eps) * g`` in one pass and the
backward produces dx per row-tile plus a per-tile partial dg that is summed
outside the kernel (cross-grid accumulation into a single [D] output is a
race under the TPU model, so partials are the portable pattern).

Wrapped in ``jax.custom_vjp`` so Layer-2 blocks differentiate through it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, want: int) -> int:
    b = min(want, n)
    while n % b != 0:
        b -= 1
    return b


def _fwd_kernel(x_ref, g_ref, y_ref, rstd_ref, *, eps):
    x = x_ref[...]  # [block_n, d]
    g = g_ref[...]  # [d]
    ms = jnp.mean(jnp.square(x), axis=-1)
    rstd = jax.lax.rsqrt(ms + eps)
    y_ref[...] = x * rstd[:, None] * g[None, :]
    rstd_ref[...] = rstd


def _bwd_kernel(x_ref, g_ref, rstd_ref, dy_ref, dx_ref, dg_ref):
    x = x_ref[...]
    g = g_ref[...]
    rstd = rstd_ref[...]
    dy = dy_ref[...]
    d = x.shape[-1]
    xhat = x * rstd[:, None]
    wdy = dy * g[None, :]
    # dx = rstd * (wdy - xhat * mean(wdy * xhat))
    c = jnp.sum(wdy * xhat, axis=-1) / d
    dx_ref[...] = rstd[:, None] * (wdy - xhat * c[:, None])
    dg_ref[...] = jnp.sum(dy * xhat, axis=0)[None, :]  # partial over this tile


def _fwd(x2, g, *, eps, block_n, interpret):
    n, d = x2.shape
    block_n = _pick_block(n, block_n)
    grid = (n // block_n,)
    y, rstd = pl.pallas_call(
        functools.partial(_fwd_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(x2, g)
    return y, rstd


def _bwd(x2, g, rstd, dy2, *, block_n, interpret):
    n, d = x2.shape
    block_n = _pick_block(n, block_n)
    nb = n // block_n
    dx, dg_part = pl.pallas_call(
        _bwd_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((nb, d), jnp.float32),
        ],
        interpret=interpret,
    )(x2, g, rstd, dy2)
    return dx, jnp.sum(dg_part, axis=0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def rmsnorm(x, g, eps=1e-6, block_n=128, interpret=True):
    """RMSNorm over the last axis. x: [..., D], g: [D] -> [..., D]."""
    shp = x.shape
    x2 = x.reshape(-1, shp[-1])
    y, _ = _fwd(x2, g, eps=eps, block_n=block_n, interpret=interpret)
    return y.reshape(shp)


def _vjp_fwd(x, g, eps, block_n, interpret):
    shp = x.shape
    x2 = x.reshape(-1, shp[-1])
    y, rstd = _fwd(x2, g, eps=eps, block_n=block_n, interpret=interpret)
    return y.reshape(shp), (x2, g, rstd, shp)


def _vjp_bwd(eps, block_n, interpret, res, dy):
    x2, g, rstd, shp = res
    dy2 = dy.reshape(-1, shp[-1])
    dx, dg = _bwd(x2, g, rstd, dy2, block_n=block_n, interpret=interpret)
    return dx.reshape(shp), dg


rmsnorm.defvjp(_vjp_fwd, _vjp_bwd)


def vmem_bytes(d: int, block_n: int, bytes_per_el: int = 4) -> int:
    """Peak VMEM per grid step: x tile, y tile, g, rstd."""
    return (2 * block_n * d + d + block_n) * bytes_per_el
