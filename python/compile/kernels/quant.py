"""Per-output-channel int8 quantization + the fused-dequant matmul kernel.

Scheme (DESIGN.md §15): a 2-D weight ``w [in, out]`` stores as
``(q int8[in, out], s float32[out])`` with ``s[c] = absmax(w[:, c]) / 127``
and ``q = clip(round_half_even(w / s), -127, 127)``; an all-zero channel
keeps ``s[c] = 0`` so dequant reproduces it exactly. Dequant is fused into
the matmul — the f32 weight tensor is never materialized:

    y = (x @ q.astype(f32)) * s[None, :]

That exact expression is the contract on BOTH backends (the jnp path in
``model._q8_lin`` evaluates it verbatim; the Pallas kernel below computes
the same product per row tile), because ``(x @ q) * s`` and ``x @ (q * s)``
round differently in f32 and the Rust differential suites pin the former.

The Rust twin of ``quantize_per_channel`` lives in ``rust/src/opt/quant.rs``
and must stay bit-identical: f32 scale division, ``round_ties_even``
(= ``np.rint``), clamp to [-127, 127].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .rmsnorm import _pick_block


def quantize_per_channel(w):
    """w f32[in, out] -> (q int8[in, out], s f32[out]). Rejects NaN/Inf."""
    w = np.asarray(w, np.float32)
    if w.ndim != 2:
        raise ValueError(f"only 2-D tensors quantize, got shape {w.shape}")
    if not np.all(np.isfinite(w)):
        raise ValueError("quantize_per_channel: NaN/Inf in weight tensor")
    s = (np.max(np.abs(w), axis=0) / 127.0).astype(np.float32)
    safe = np.where(s > 0, s, 1.0).astype(np.float32)
    q = np.rint((w / safe[None, :]).astype(np.float32))
    q = np.clip(q, -127.0, 127.0).astype(np.int8)
    q = np.where(s[None, :] > 0, q, 0).astype(np.int8)
    return q, s


def dequantize(q, s):
    """Reference dequant (tests only — the runtime never materializes it)."""
    return np.asarray(q, np.float32) * np.asarray(s, np.float32)[None, :]


def _q8_kernel(x_ref, q_ref, s_ref, y_ref):
    x = x_ref[...]                                  # [block_n, din]
    qf = q_ref[...].astype(jnp.float32)             # [din, dout]
    s = s_ref[...]                                  # [dout]
    y = jnp.dot(x, qf, preferred_element_type=jnp.float32)
    y_ref[...] = y * s[None, :]


def _q8_fwd(x2, q, s, block_n, interpret):
    n, din = x2.shape
    dout = q.shape[1]
    bn = _pick_block(n, block_n)
    return pl.pallas_call(
        _q8_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, din), lambda i: (i, 0)),
            pl.BlockSpec((din, dout), lambda i: (0, 0)),
            pl.BlockSpec((dout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dout), jnp.float32),
        interpret=interpret,
    )(x2, q, s)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _q8_mm(x2, q, s, block_n, interpret):
    return _q8_fwd(x2, q, s, block_n, interpret)


def _q8_vjp_fwd(x2, q, s, block_n, interpret):
    return _q8_fwd(x2, q, s, block_n, interpret), (q, s)


def _q8_vjp_bwd(block_n, interpret, res, dy):
    q, s = res
    # dx = (dy * s) @ dequant(q)^T — plain jnp: the weights are frozen by
    # construction (only frozen tensors quantize), so dq/ds are never used.
    dx = (dy * s[None, :]) @ q.astype(jnp.float32).T
    return dx, np.zeros(q.shape, jax.dtypes.float0), jnp.zeros_like(s)


_q8_mm.defvjp(_q8_vjp_fwd, _q8_vjp_bwd)


def q8_matmul(x, q, s, block_n=128, interpret=True):
    """Fused dequant matmul: x f32[..., in] @ (q i8[in, out], s f32[out])
    -> f32[..., out], computed as ``(x @ q.f32) * s`` per row tile."""
    shp = x.shape
    x2 = x.reshape(-1, shp[-1])
    y = _q8_mm(x2, q, s, block_n, interpret)
    return y.reshape((*shp[:-1], q.shape[1]))


def vmem_bytes(din: int, dout: int, block_n: int) -> int:
    """Peak VMEM per grid step: x tile (f32), q (i8), s + y tile (f32)."""
    return 4 * (block_n * din + dout + block_n * dout) + din * dout
