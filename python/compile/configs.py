"""Model configurations shared by the AOT exporter and (via manifest.json)
the Rust coordinator.

Trainable configs are sized for the CPU-PJRT testbed; the paper-scale
entries (TinyLlama / Mistral-7B / LLaMA-2-7B / LLaMA-2-70B / GPT2-small)
exist only for the analytical memory model (Table 1 / Fig 3) and are never
lowered to artifacts.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    vocab: int
    seq: int
    batch: int            # micro-batch baked into the artifact shapes
    mlp_ratio: int = 4
    lora_rank: int = 16
    lora_alpha: float = 32.0
    # Pallas tile sizes (TPU-aligned where the model allows; divisors of the
    # relevant dims are picked automatically by the kernels otherwise).
    block_q: int = 128
    block_k: int = 128
    block_n: int = 128    # rmsnorm row tile
    xent_block_n: int = 8
    # Paged decode (ABI v2, DESIGN.md §12): K/V page size in token slots.
    page_t: int = 16

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def pages_per_row(self) -> int:
        """Page-table width: pages needed to cover the [T] decode window."""
        return -(-self.seq // self.page_t)

    @property
    def page_n(self) -> int:
        """Pool pages per layer-half: page 0 is the reserved scratch page
        (vacant rows write there, nothing reads it), `batch * pages_per_row`
        covers every row's worst case, and one extra row's worth is
        headroom so prefix-cache retention never starves admission."""
        return (self.batch + 1) * self.pages_per_row + 1

    @property
    def d_ff(self) -> int:
        return self.mlp_ratio * self.d_model

    def block_param_shapes(self):
        d, f = self.d_model, self.d_ff
        return [
            ("g1", (d,)), ("wq", (d, d)), ("wk", (d, d)), ("wv", (d, d)),
            ("wo", (d, d)), ("g2", (d,)), ("w1", (d, f)), ("w2", (f, d)),
        ]

    def lora_param_shapes(self):
        d, f, r = self.d_model, self.d_ff, self.lora_rank
        out = []
        for nm, din, dout in [("q", d, d), ("k", d, d), ("v", d, d),
                              ("o", d, d), ("1", d, f), ("2", f, d)]:
            out.append((f"a{nm}", (din, r)))
            out.append((f"b{nm}", (r, dout)))
        return out

    def embed_param_shapes(self):
        return [("emb", (self.vocab, self.d_model)),
                ("pos", (self.seq, self.d_model))]

    def head_param_shapes(self):
        return [("gf", (self.d_model,)),
                ("wh", (self.d_model, self.vocab))]

    def n_params(self) -> int:
        total = 0
        for shapes in (self.embed_param_shapes(), self.head_param_shapes()):
            for _, s in shapes:
                n = 1
                for x in s:
                    n *= x
                total += n
        per_block = 0
        for _, s in self.block_param_shapes():
            n = 1
            for x in s:
                n *= x
            per_block += n
        return total + self.n_layers * per_block


CONFIGS = {
    c.name: c for c in [
        ModelConfig("tiny", d_model=128, n_layers=4, n_heads=4, vocab=512,
                    seq=64, batch=2, lora_rank=8),
        ModelConfig("small", d_model=256, n_layers=6, n_heads=8, vocab=2048,
                    seq=128, batch=4, lora_rank=16),
        ModelConfig("base", d_model=512, n_layers=8, n_heads=8, vocab=8192,
                    seq=128, batch=4, lora_rank=32),
        ModelConfig("e2e100m", d_model=768, n_layers=12, n_heads=12,
                    vocab=16384, seq=256, batch=2, lora_rank=64),
    ]
}
