//! Quickstart: fine-tune a small transformer with LISA and compare it with
//! full-parameter training — the 60-second tour of the public API.
//!
//! ```bash
//! make artifacts                       # once: AOT-lower the JAX segments
//! cargo run --release --example quickstart
//! ```

use std::path::Path;

use lisa::data::{corpus, encode_sft, split_train_val, DataLoader, Tokenizer};
use lisa::eval;
use lisa::runtime::Runtime;
use lisa::strategy::StrategySpec;
use lisa::train::{TrainConfig, TrainSession};

fn main() -> anyhow::Result<()> {
    lisa::util::logger::init();

    // 1. A runtime = one model config's AOT artifacts + a PJRT CPU client.
    let rt = Runtime::load(Path::new("artifacts/tiny"), "pallas")?;
    let m = rt.manifest.clone();
    println!("model: {:.1}M params, {} layers", m.n_params as f64 / 1e6, m.n_layers);

    // 2. Synthetic instruction corpus -> tokenizer -> batches.
    let samples = corpus::gen_instruction_corpus(256, 42);
    let tok = Tokenizer::build(&corpus::sample_texts(&samples), m.vocab);
    let (train, val) = split_train_val(&samples, 0.1, 7);
    let enc = |xs: &[corpus::Sample]| xs.iter().map(|s| encode_sft(&tok, s, m.seq)).collect::<Vec<_>>();
    let mut train_dl = DataLoader::new(enc(&train), m.batch, m.seq, 1);
    let val_dl = DataLoader::new(enc(&val), m.batch, m.seq, 1);

    // 3. Train with LISA (γ=2 layers unfrozen, resampled every K=5 steps)
    //    and with full-parameter AdamW for comparison. Any name from
    //    `strategy::registry()` works here — `lisa exp list` prints them.
    for spec in [StrategySpec::lisa(2, 5), StrategySpec::ft()] {
        let cfg = TrainConfig { steps: 40, lr: 3e-3, seed: 42, log_every: 10, ..Default::default() };
        let mut sess = TrainSession::new(&rt, &spec, cfg)?;
        let label = sess.label();
        let res = sess.run(&mut train_dl)?;
        let params = sess.eval_params();
        let rep = eval::evaluate(&mut sess.engine, &params, &val_dl)?;
        println!(
            "[{label:>4}] loss {:.3} -> {:.3} | val ppl {:.1} | {:.0} ms/step | peak mem {}",
            res.loss_curve.first().unwrap().1,
            res.final_train_loss,
            rep.ppl,
            res.median_step_ms(),
            lisa::util::table::human_bytes(res.peak_mem),
        );
    }
    Ok(())
}
