//! Domain-specific fine-tuning (paper §4.4's PubMedQA setting): LISA vs
//! LoRA on the synthetic medical-QA grammar, judged by yes/no/maybe
//! exact-match.
//!
//! ```bash
//! cargo run --release --example medical_qa
//! ```

use std::path::Path;

use lisa::data::{corpus, encode_sft, split_train_val, DataLoader, Tokenizer};
use lisa::eval;
use lisa::runtime::Runtime;
use lisa::strategy::StrategySpec;
use lisa::train::{TrainConfig, TrainSession};

fn main() -> anyhow::Result<()> {
    lisa::util::logger::init();
    let rt = Runtime::load(Path::new("artifacts/tiny"), "pallas")?;
    let m = rt.manifest.clone();

    let samples = corpus::gen_medqa(320, 21);
    let tok = Tokenizer::build(&corpus::sample_texts(&samples), m.vocab);
    let (tr, te) = split_train_val(&samples, 0.2, 3);
    let enc = |xs: &[corpus::Sample]| xs.iter().map(|s| encode_sft(&tok, s, m.seq)).collect::<Vec<_>>();
    let mut train_dl = DataLoader::new(enc(&tr), m.batch, m.seq, 4);
    let test_dl = DataLoader::new(enc(&te), m.batch, m.seq, 4);

    for spec in [StrategySpec::lisa(2, 5), StrategySpec::lora()] {
        let cfg = TrainConfig { steps: 50, lr: 3e-3, seed: 11, log_every: 0, ..Default::default() };
        let mut sess = TrainSession::new(&rt, &spec, cfg)?;
        let label = sess.label();
        let res = sess.run(&mut train_dl)?;
        let p = sess.eval_params();
        let rep = eval::evaluate(&mut sess.engine, &p, &test_dl)?;
        println!(
            "[{label:>4}] PubMedQA-proxy EM {:.1}%  (train loss {:.3}, peak mem {})",
            100.0 * rep.exact_match,
            res.final_train_loss,
            lisa::util::table::human_bytes(res.peak_mem),
        );
    }
    Ok(())
}
