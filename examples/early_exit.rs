//! Early-exit (DoLa-style) inspection: evaluate exact-match when logits are
//! read from intermediate depths of a LISA-trained model (paper Table 12).
//!
//! ```bash
//! cargo run --release --example early_exit
//! ```

use std::path::Path;

use lisa::data::{corpus, encode_sft, split_train_val, DataLoader, Tokenizer};
use lisa::eval;
use lisa::runtime::Runtime;
use lisa::strategy::StrategySpec;
use lisa::train::{TrainConfig, TrainSession};

fn main() -> anyhow::Result<()> {
    lisa::util::logger::init();
    let rt = Runtime::load(Path::new("artifacts/tiny"), "pallas")?;
    let m = rt.manifest.clone();

    let problems = corpus::gen_math_problems(240, 4, 2);
    let tok = Tokenizer::build(&corpus::sample_texts(&problems), m.vocab);
    let (tr, te) = split_train_val(&problems, 0.25, 5);
    let enc = |xs: &[corpus::Sample]| xs.iter().map(|s| encode_sft(&tok, s, m.seq)).collect::<Vec<_>>();
    let mut train_dl = DataLoader::new(enc(&tr), m.batch, m.seq, 2);
    let test_dl = DataLoader::new(enc(&te), m.batch, m.seq, 2);

    let cfg = TrainConfig { steps: 60, lr: 3e-3, seed: 6, log_every: 20, ..Default::default() };
    let mut sess = TrainSession::new(&rt, &StrategySpec::lisa(2, 5), cfg)?;
    sess.run(&mut train_dl)?;
    let params = sess.eval_params();

    println!("exit depth -> GSM8K-proxy exact match");
    for depth in 1..=m.n_layers {
        let em = eval::exact_match_at_depth(&mut sess.engine, &params, &test_dl, depth)?;
        println!("  {depth:>2}/{}: {:>5.1}%", m.n_layers, 100.0 * em);
    }
    Ok(())
}
