//! Continual pre-training pipeline (the paper's §4.3 workflow): pre-train
//! on an arithmetic corpus with LISA, checkpoint, fine-tune on word
//! problems, report exact-match — end to end through the public API.
//!
//! ```bash
//! cargo run --release --example continual_pretrain_math
//! ```

use std::path::Path;

use lisa::data::{corpus, encode_lm_stream, encode_sft, split_train_val, DataLoader, Tokenizer};
use lisa::eval;
use lisa::model::checkpoint;
use lisa::runtime::Runtime;
use lisa::strategy::StrategySpec;
use lisa::train::{TrainConfig, TrainSession};

fn main() -> anyhow::Result<()> {
    lisa::util::logger::init();
    let rt = Runtime::load(Path::new("artifacts/tiny"), "pallas")?;
    let m = rt.manifest.clone();

    // Shared vocabulary across both stages.
    let docs = corpus::gen_cpt_math_docs(160, 6, 3);
    let problems = corpus::gen_math_problems(240, 4, 2);
    let mut texts = docs.clone();
    texts.extend(corpus::sample_texts(&problems));
    let tok = Tokenizer::build(&texts, m.vocab);

    // Stage 1: continual pre-training (plain LM objective) with LISA γ=L/2.
    let mut cpt_dl = DataLoader::new(encode_lm_stream(&tok, &docs, m.seq), m.batch, m.seq, 1);
    let gamma = (m.n_layers / 2).max(1);
    let cfg = TrainConfig { steps: 40, lr: 3e-3, seed: 9, log_every: 10, ..Default::default() };
    let mut sess = TrainSession::new(&rt, &StrategySpec::lisa(gamma, 5), cfg)?;
    let res = sess.run(&mut cpt_dl)?;
    println!("CPT: loss {:.3} -> {:.3}", res.loss_curve[0].1, res.final_train_loss);

    // Checkpoint between stages (binary format, see model::checkpoint).
    let ckpt = std::env::temp_dir().join("lisa_cpt_example.ckpt");
    checkpoint::save_model(&ckpt, &sess.params)?;
    println!("checkpoint: {}", ckpt.display());

    // Stage 2: supervised fine-tune on word problems from the checkpoint.
    let (tr, te) = split_train_val(&problems, 0.25, 5);
    let enc = |xs: &[corpus::Sample]| xs.iter().map(|s| encode_sft(&tok, s, m.seq)).collect::<Vec<_>>();
    let mut train_dl = DataLoader::new(enc(&tr), m.batch, m.seq, 2);
    let test_dl = DataLoader::new(enc(&te), m.batch, m.seq, 2);

    let mut params = lisa::model::ModelParams::init(&m, &mut lisa::util::rng::Rng::new(0));
    checkpoint::load_model(&ckpt, &mut params)?;
    let cfg = TrainConfig { steps: 40, lr: 3e-3, seed: 10, log_every: 10, ..Default::default() };
    let mut ft = TrainSession::with_params(&rt, &StrategySpec::lisa(gamma, 5), cfg, params)?;
    ft.run(&mut train_dl)?;
    let p = ft.eval_params();
    let rep = eval::evaluate(&mut ft.engine, &p, &test_dl)?;
    println!(
        "GSM8K-proxy: exact match {:.1}% (token acc {:.2})",
        100.0 * rep.exact_match,
        rep.token_acc
    );
    Ok(())
}
