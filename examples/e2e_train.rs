//! The end-to-end driver (EXPERIMENTS.md §End-to-End): train the largest
//! exported config with LISA on the instruction corpus for a few hundred
//! steps, logging the loss curve, throughput, memory and a per-segment
//! profile, then checkpoint + evaluate.
//!
//! ```bash
//! make artifacts CONFIGS=e2e100m          # ~110M-parameter artifacts
//! cargo run --release --example e2e_train -- --config e2e100m --steps 200
//! # CPU-budget alternative (35M params):
//! cargo run --release --example e2e_train -- --config base --steps 200
//! ```

use lisa::exp::{self, Ctx};
use lisa::util::cli::Args;

fn main() -> anyhow::Result<()> {
    lisa::util::logger::init();
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::parse(
        &raw,
        &[
            ("config", "base", "model config to run"),
            ("steps", "200", "training steps"),
            ("backend", "pallas", "kernel backend"),
            ("seed", "42", "seed"),
        ],
    )?;
    let ctx = Ctx {
        artifacts: "artifacts".into(),
        results: "results".into(),
        backend: a.get("backend"),
        scale: 1.0,
        seed: a.get_u64("seed")?,
    };
    exp::e2e::e2e(&ctx, &a.get("config"), Some(a.get_usize("steps")?))
}
